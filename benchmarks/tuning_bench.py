"""Auto-tuned vs fixed-φ parameters across the paper's instance families.

For each family — List(γ∈{0, 0.5, 1}) and both Euler-tour tree models —
runs the solver twice on identical inputs: once with the fixed
φ = 1/32 ruler fraction (the legacy default) and once auto-tuned
(``ruler_fraction=None`` → per-level r* from the §2.6 cost model via
``tuner.level_plan``). Measures CPU wall time plus counted
rounds/messages, and projects the **modeled 24576-core time** (the
paper's largest configuration) from the counted per-PE loads with
SuperMUC alpha/beta constants — the α·startup effects that motivate r*
do not show on one CPU, the counted rounds do.

Results land in benchmarks/results/tuning.json (+ a markdown table on
stdout for EXPERIMENTS.md). ``BENCH_QUICK=1`` shrinks the instances to
a CI smoke size.
"""
import json
import os
import pathlib
import sys

HERE = pathlib.Path(__file__).parent
RESULTS = HERE / "results"
sys.path.insert(0, str(HERE.parent / "src"))
sys.path.insert(0, str(HERE))

from _common import modeled_large_p, run_worker  # noqa: E402

QUICK = os.environ.get("BENCH_QUICK", "0") == "1"
P = 4 if QUICK else 16
MESH = (2, 2) if QUICK else (4, 4)
NPE = 1 << 11 if QUICK else 1 << 14
ITERS = 1 if QUICK else 3
P_MODEL = 24576
D = 2  # grid indirection on the 2-axis bench mesh


ALL_FAMILIES = [
    ("list_g0.0", {"instance": "list", "gamma": 0.0}),
    ("list_g0.5", {"instance": "list", "gamma": 0.5}),
    ("list_g1.0", {"instance": "list", "gamma": 1.0}),
    ("euler_local", {"instance": "euler_local"}),
    ("euler_random", {"instance": "euler_random"}),
]
#: CI smoke: the two families with the widest auto-vs-fixed margin —
#: enough to catch a tuning regression without 10 worker compiles.
QUICK_FAMILIES = [ALL_FAMILIES[2], ALL_FAMILIES[4]]
FAMILIES = QUICK_FAMILIES if QUICK else ALL_FAMILIES
#: the bench fails unless auto wins on this many families (the full
#: floor is the ISSUE acceptance criterion; QUICK keeps a margin for
#: small-instance noise).
WINS_FLOOR = 1 if QUICK else 3

CONFIGS = [
    ("fixed_1/32", {"ruler_fraction": 1 / 32}),
    ("auto_tuned", {"ruler_fraction": None, "machine": "supermuc"}),
]


def main():
    rows = []
    for fam, fam_kw in FAMILIES:
        for cfg_name, cfg_kw in CONFIGS:
            spec = dict(p=P, mesh=MESH, n_per_pe=NPE, algorithm="srs",
                        srs_rounds=2, contraction=True, indirection="grid",
                        iters=ITERS, seed=1)
            spec.update(fam_kw)
            spec.update(cfg_kw)
            r = run_worker(spec)
            rows.append({
                "family": fam,
                "config": cfg_name,
                "n": r["n"],
                "p": P,
                "delta_locality": r["delta_locality"],
                "wall_s_min": r["wall_s_min"],
                "rounds": r["stats"]["rounds"] // P,
                "pd_rounds": r["stats"]["pd_rounds"] // P,
                "rulers": r["stats"]["rulers"],
                "sub_size": r["stats"]["sub_size"],
                "chase_msgs": r["stats"]["chase_msgs"],
                "pd_msgs": r["stats"]["pd_msgs"],
                "attempts": r["stats"]["attempts"],
                "modeled_24576_s": modeled_large_p(r["stats"], P,
                                                   P_MODEL, D),
            })
            print(f"tuning/{fam}/{cfg_name},"
                  f"{rows[-1]['wall_s_min'] * 1e6:.1f},"
                  f"modeled_s={rows[-1]['modeled_24576_s']:.5f};"
                  f"rounds={rows[-1]['rounds']}")

    # verdict: on how many families does auto-tuning beat fixed phi?
    wins = 0
    table = ["| family | δ | fixed rounds | auto rounds | fixed modeled "
             "24576-core s | auto modeled s | auto wins |",
             "|---|---|---|---|---|---|---|"]
    for fam, _ in FAMILIES:
        fx = next(r for r in rows
                  if r["family"] == fam and r["config"] == "fixed_1/32")
        au = next(r for r in rows
                  if r["family"] == fam and r["config"] == "auto_tuned")
        win = au["modeled_24576_s"] <= fx["modeled_24576_s"]
        wins += int(win)
        table.append(
            f"| {fam} | {fx['delta_locality']:.2f} "
            f"| {fx['rounds']}+{fx['pd_rounds']} "
            f"| {au['rounds']}+{au['pd_rounds']} "
            f"| {fx['modeled_24576_s']:.5f} | {au['modeled_24576_s']:.5f} "
            f"| {'yes' if win else 'no'} |")
    print("\n".join(table))
    print(f"# auto-tuned wins on {wins}/{len(FAMILIES)} families")

    # gate before touching the committed artifact: a regressed run must
    # not clobber the known-good results it is being compared against
    assert all(r["attempts"] == 1 for r in rows), \
        "capacity retries fired on a default config — specs undersized"
    assert wins >= WINS_FLOOR, \
        f"auto-tuning regressed: {wins}/{len(FAMILIES)} wins < {WINS_FLOOR}"

    RESULTS.mkdir(exist_ok=True)
    out = {"quick": QUICK, "p": P, "n_per_pe": NPE,
           "p_model": P_MODEL, "wins": wins,
           "families": len(FAMILIES), "rows": rows,
           "table_md": "\n".join(table)}
    dst = RESULTS / ("tuning_quick.json" if QUICK else "tuning.json")
    dst.write_text(json.dumps(out, indent=1))
    print(f"# wrote {dst}")


if __name__ == "__main__":
    main()
