"""Benchmark harness — one function per paper figure/table.

  fig2_locality    Fig 2: locality-aware techniques vs gamma
  fig3_scaling     Fig 3: weak scaling, SRS vs PD, +/- indirection
  fig4_indirection Fig 4: indirection schemes + phase breakdown
  treealg_bench    Euler-tour tree statistics per tree family + the
                   batched multi-instance front door
  graphalg_bench   connectivity + spanning-forest statistics per edge
                   family (the hooking pipeline's second comm pattern)
  simshard_bench   virtual-PE scaling sweep: the full solver at
                   p = 8..1024 in ONE process (transport.sim_mesh)
  roofline         the (arch x shape) roofline table from the dry-run
                   artifacts (see repro.launch.dryrun)

Output: ``name,us_per_call,derived`` CSV lines (harness contract), with
the full measurements written to benchmarks/results/*.json.

This container measures wall time on CPU "virtual PEs" (devices
oversubscribe cores), so absolute times are not TPU predictions. Each
row therefore also derives the *modeled* communication time from the
counted messages/rounds via the paper's alpha-beta model (§2.6) with
SuperMUC-like constants — that is what reproduces the paper's trends —
plus the measured message/round counts that validate the paper's
analytical predictions (rounds ~ n/r, |sub| ~ r ln(n/r), 2x volume for
indirection).
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import pathlib
import subprocess
import sys

HERE = pathlib.Path(__file__).parent
RESULTS = HERE / "results"
sys.path.insert(0, str(HERE.parent / "src"))

from repro.core.listrank import analysis  # noqa: E402
from repro.core.listrank.api import CHASE_WIRE_WORDS  # noqa: E402

QUICK = os.environ.get("BENCH_QUICK", "0") == "1"
P_BENCH = 8 if QUICK else 16
NPE = 1 << 13 if QUICK else 1 << 15
ITERS = 2 if QUICK else 3


def _run_worker(spec: dict) -> dict:
    cmd = [sys.executable, str(HERE / "_worker.py"), json.dumps(spec)]
    proc = subprocess.run(cmd, capture_output=True, text=True, timeout=3600)
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(f"worker failed: {proc.stdout[-500:]}\n"
                       f"{proc.stderr[-2000:]}")


def _modeled_seconds(stats: dict, p: int, hops: int) -> float:
    """alpha-beta time from counted messages (wire-format words each)
    and rounds."""
    m = analysis.SUPERMUC
    rounds = max(stats.get("rounds", 0) // p, 1)
    msgs = stats.get("chase_msgs", 0) + stats.get("pd_msgs", 0) \
        + stats.get("fixup_msgs", 0) + stats.get("reversal_msgs", 0)
    words_per_pe = float(CHASE_WIRE_WORDS) * msgs / p
    startups = rounds * hops * (p ** (1.0 / max(hops, 1)))
    return m.alpha * startups + m.beta * words_per_pe


def _emit(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")


def fig2_locality() -> list[dict]:
    """Fig 2: PLAIN vs LOCALCONTRACTION over gamma (no indirection)."""
    rows = []
    gammas = [0.0, 0.5, 1.0] if QUICK else [0.0, 0.25, 0.5, 0.75, 1.0]
    for gamma in gammas:
        for variant, contraction in (("plain", False),
                                     ("localcontraction", True)):
            spec = dict(p=P_BENCH, mesh=None, n_per_pe=NPE, gamma=gamma,
                        algorithm="srs", srs_rounds=2,
                        contraction=contraction, indirection="direct",
                        iters=ITERS)
            r = _run_worker(spec)
            r.update(gamma=gamma, variant=variant)
            rows.append(r)
            _emit(f"fig2/{variant}/g{gamma}", r["wall_s_mean"] * 1e6,
                  f"msgs={r['stats']['chase_msgs']};"
                  f"delta={r['delta_locality']:.2f}")
    return rows


def fig3_scaling() -> list[dict]:
    """Fig 3: weak scaling SRS/PD x direct/indirect."""
    rows = []
    ps = [4, 16] if QUICK else [4, 8, 16]
    for p in ps:
        mesh = {4: (2, 2), 8: (2, 4), 16: (4, 4)}[p]
        for algo in ("srs", "doubling"):
            for ind in ("direct", "grid"):
                spec = dict(p=p, mesh=mesh, n_per_pe=NPE, gamma=1.0,
                            algorithm=algo, srs_rounds=2, contraction=True,
                            indirection=ind, iters=ITERS)
                r = _run_worker(spec)
                hops = 2 if ind == "grid" else 1
                r.update(p=p, algorithm=algo, indirection=ind,
                         modeled_s=_modeled_seconds(r["stats"], p, hops))
                rows.append(r)
                _emit(f"fig3/{algo}+{ind}/p{p}", r["wall_s_mean"] * 1e6,
                      f"modeled_s={r['modeled_s']:.4f};"
                      f"rounds={r['stats']['rounds'] // p}")
    return rows


def fig4_indirection() -> list[dict]:
    """Fig 4: direct vs 2D-grid vs topology-aware + phase breakdown."""
    rows = []
    for ind, hops in (("direct", 1), ("grid", 2), ("topo", 2)):
        spec = dict(p=P_BENCH, mesh=None, n_per_pe=NPE, gamma=1.0,
                    algorithm="srs", srs_rounds=2, contraction=True,
                    indirection=ind, iters=ITERS)
        r = _run_worker(spec)
        st = r["stats"]
        r.update(indirection=ind,
                 modeled_s=_modeled_seconds(st, P_BENCH, hops),
                 phase_msgs={"chase": st["chase_msgs"],
                             "base": st["pd_msgs"],
                             "propagate+fix": st["fixup_msgs"]})
        rows.append(r)
        _emit(f"fig4/{ind}", r["wall_s_mean"] * 1e6,
              f"modeled_s={r['modeled_s']:.4f};"
              f"chase={st['chase_msgs']};pd={st['pd_msgs']};"
              f"fix={st['fixup_msgs']}")
    return rows


def _subprocess_bench(prefix: str, script: str,
                      quick_artifact: bool = True,
                      artifact: str | None = None) -> list[dict]:
    """Run a standalone bench script in a subprocess (its virtual-
    device count must be fixed before jax initializes) and re-emit its
    CSV rows. Quick mode reads the script's own *_quick.json artifact
    where one exists — the committed <prefix>.json is full-mode only
    and must not be mistaken for a quick run's data."""
    proc = subprocess.run([sys.executable, str(HERE / script)],
                          capture_output=True, text=True, timeout=3600)
    for line in proc.stdout.splitlines():
        if line.startswith(f"{prefix}/"):
            print(line)
    if proc.returncode != 0:
        print(f"{prefix}/error,0,rc={proc.returncode}")
        print(proc.stderr[-1000:])
        return []
    stem = artifact or prefix
    f = RESULTS / (f"{stem}_quick.json" if QUICK and quick_artifact
                   else f"{stem}.json")
    return json.loads(f.read_text()) if f.exists() else []


def exchange_micro() -> list[dict]:
    """Exchange-layer microbenchmark (packed vs unpacked wire)."""
    return _subprocess_bench("exchange", "exchange_bench.py",
                             quick_artifact=False)


def treealg_bench() -> list[dict]:
    """Tree-statistics + batched-front-door benchmark."""
    return _subprocess_bench("treealg", "treealg_bench.py")


def graphalg_bench() -> list[dict]:
    """Connectivity + graph_stats benchmark."""
    return _subprocess_bench("graphalg", "graphalg_bench.py")


def simshard_bench() -> list[dict]:
    """Virtual-PE scaling sweep (needs no device flags — the simshard
    backend is in-process by construction; the subprocess only isolates
    its memory)."""
    return _subprocess_bench("simshard", "simshard_bench.py")


def recovery_bench() -> list[dict]:
    """Resume-from-level-k vs full restart + the sampled-splitter
    estimation pre-pass (writes recovery.json in both modes — the
    artifact records its own quick flag)."""
    return _subprocess_bench("recovery", "recovery_bench.py",
                             quick_artifact=False)


def obs_residual_bench() -> list[dict]:
    """Per-stage model-vs-measured residual tables for all five
    instance families (the flight-recorder gate)."""
    return _subprocess_bench("obs", "obs_residuals.py",
                             artifact="obs_residuals")


def roofline() -> list[dict]:
    """Aggregate the dry-run JSON artifacts into the roofline table."""
    rows = []
    src = RESULTS / "dryrun"
    if not src.exists():
        print("roofline,0,missing (run python -m repro.launch.dryrun --all)")
        return rows
    for f in sorted(src.glob("*.json")):
        rec = json.loads(f.read_text())
        if "skipped" in rec:
            _emit(f"roofline/{rec['arch']}/{rec['shape']}", 0.0, "skipped")
            continue
        ro = rec["roofline"]
        rows.append(rec)
        _emit(f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}",
              ro["step_time_bound_s"] * 1e6,
              f"bottleneck={ro['bottleneck']};mfu<={ro['mfu_bound']:.3f};"
              f"useful={ro['useful_flops_ratio']:.2f}")
    return rows


# --------------------------------------------------------------------------
# trajectory (perf trend records)
# --------------------------------------------------------------------------

def _git_rev() -> str:
    try:
        proc = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                              capture_output=True, text=True,
                              cwd=str(HERE.parent), timeout=30)
        rev = proc.stdout.strip()
        return rev if proc.returncode == 0 and rev else "unknown"
    except OSError:
        return "unknown"


def _headline(name: str, data) -> dict:
    """Compact per-bench headline numbers for the trend record."""
    if isinstance(data, dict):
        # structured artifacts (tuning/graphalg/recovery/...): keep the
        # scalar top-level fields, count the list-valued sections
        h = {k: v for k, v in data.items()
             if isinstance(v, (int, float, bool))
             or (isinstance(v, str) and len(v) <= 80)}
        h["rows"] = sum(len(v) for v in data.values()
                        if isinstance(v, list))
        return h
    if not isinstance(data, list) or not data:
        return {"rows": 0}
    h = {"rows": len(data)}
    walls = [r["wall_s_mean"] for r in data
             if isinstance(r, dict) and "wall_s_mean" in r]
    if walls:
        h["wall_s_mean"] = sum(walls) / len(walls)
    if name.startswith("obs"):
        summaries = [r.get("summary", {}) for r in data
                     if isinstance(r, dict)]
        meas = sum(s.get("measured_s", 0.0) for s in summaries)
        pred = sum(s.get("predicted_s", 0.0) for s in summaries)
        h.update(measured_s=meas, predicted_s=pred,
                 families_ok=sum(1 for r in data
                                 if isinstance(r, dict) and r.get("ok")))
    return h


def summarize(write: bool = True) -> dict:
    """Merge benchmarks/results/*.json into one trajectory record and
    append it to benchmarks/results/trajectory.jsonl.

    Schema per line: ``{"ts", "unix", "git_rev", "quick",
    "benches": {<result-file-stem>: headline}}`` — the perf trend the
    BENCH harness tracks across commits.
    """
    now = datetime.datetime.now(datetime.timezone.utc)
    record = {
        "ts": now.isoformat(timespec="seconds"),
        "unix": now.timestamp(),
        "git_rev": _git_rev(),
        "quick": QUICK,
        "benches": {},
    }
    for f in sorted(RESULTS.glob("*.json")):
        if f.name == "benchmarks.json":
            continue
        try:
            data = json.loads(f.read_text())
        except (OSError, json.JSONDecodeError):
            continue
        if isinstance(data, dict) and "traceEvents" in data:
            continue  # Chrome-trace artifacts are not bench results
        record["benches"][f.stem] = _headline(f.stem, data)
    bj = RESULTS / "benchmarks.json"
    if bj.exists():
        try:
            top = json.loads(bj.read_text())
            for name, data in top.items():
                record["benches"].setdefault(name, _headline(name, data))
        except (OSError, json.JSONDecodeError):
            pass
    if write:
        with open(RESULTS / "trajectory.jsonl", "a") as fh:
            fh.write(json.dumps(record) + "\n")
        print(f"# appended trend record ({record['git_rev']}, "
              f"{len(record['benches'])} benches) to "
              f"{RESULTS / 'trajectory.jsonl'}")
    return record


def main() -> None:
    RESULTS.mkdir(exist_ok=True)
    out = {}
    print("name,us_per_call,derived")
    out["exchange"] = exchange_micro()
    out["fig2_locality"] = fig2_locality()
    out["fig3_scaling"] = fig3_scaling()
    out["fig4_indirection"] = fig4_indirection()
    out["treealg"] = treealg_bench()
    out["graphalg"] = graphalg_bench()
    out["simshard"] = simshard_bench()
    out["recovery"] = recovery_bench()
    out["obs"] = obs_residual_bench()
    out["roofline"] = roofline()
    (RESULTS / "benchmarks.json").write_text(json.dumps(out, indent=1))
    print(f"# wrote {RESULTS / 'benchmarks.json'}")
    summarize()


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--summary", action="store_true",
                    help="merge benchmarks/results/*.json into one "
                         "trajectory record appended to "
                         "results/trajectory.jsonl (no benches run)")
    ns = ap.parse_args()
    if ns.summary:
        summarize()
    else:
        main()
