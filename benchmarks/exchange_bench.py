"""Exchange-layer microbenchmark: collectives and per-round time.

Routes a realistic chase-round message queue (destination distribution
drawn from the Fig-3 weak-scaling instance: gamma=1 random list,
n_per_pe elements per PE) through ``exchange.route`` on direct and
2D-grid indirection, with the packed wire format on and off, and
records

  * the number of ``all_to_all`` collectives per routing round,
    counted by jaxpr inspection (the §2.6 alpha term), and
  * measured wall time per round on the host-device mesh (CPU "virtual
    PEs" here — trends, not TPU predictions).

Output: ``name,us_per_call,derived`` CSV lines (harness contract) and
benchmarks/results/exchange.json. Standalone:

  BENCH_QUICK=1 python benchmarks/exchange_bench.py
"""
from __future__ import annotations

import json
import os
import pathlib
import sys
import time

HERE = pathlib.Path(__file__).parent
QUICK = os.environ.get("BENCH_QUICK", "0") == "1"
# quick mode uses the p=4 point of the Fig-3 weak-scaling sweep — fewer
# virtual devices per core => far less scheduler noise in the timings.
P_BENCH = 4 if QUICK else 16
MESH = (2, 2) if QUICK else (4, 4)
NPE = 1 << 13 if QUICK else 1 << 15
ROUNDS = 7 if QUICK else 12
CHAIN = 8  # route rounds chained inside one jitted call

os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={P_BENCH}")
sys.path.insert(0, str(HERE.parent / "src"))

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.core.listrank import analysis, instances, introspect  # noqa: E402
from repro.core.listrank.api import CHASE_WIRE_WORDS  # noqa: E402
from repro.core.listrank.config import IndirectionSpec  # noqa: E402
from repro.core.listrank.exchange import MeshPlan, route  # noqa: E402

AXES = ("row", "col")


def chase_queue(n: int, p: int, seed: int = 1):
    """A chase-round message batch over the Fig-3 instance: targets are
    successor ids of random elements, i.e. the real wave-destination
    distribution of the weak-scaling run."""
    succ, rank = instances.gen_list(n, gamma=1.0, seed=seed)
    rng = np.random.default_rng(seed + 1)
    m = n // p
    q = max(64, m // 32)  # ~queue load of a chase round per PE
    src = rng.integers(0, n, p * q)
    payload = {
        "target": jnp.asarray(succ[src], jnp.int32),
        "ruler": jnp.asarray(src, jnp.int32),
        "weight": jnp.asarray(rank[src], jnp.int32),
    }
    dest = jnp.asarray(succ[src] // m, jnp.int32)
    valid = jnp.ones(p * q, bool)
    return payload, dest, valid, q


def build_fn(mesh, plan, caps, keys, chain=1):
    q = None

    def fn(*leaves):
        pl = dict(zip(keys, leaves[:-2]))
        dest, valid = leaves[-2], leaves[-1]
        n = dest.shape[0]
        acc = jnp.int32(0)
        for _ in range(chain):
            d, dv, lo, st = route(plan, caps, pl, dest, valid)
            # data dependency between rounds so XLA cannot collapse them
            pl = dict(pl, ruler=pl["ruler"] ^ d["ruler"][:n])
            acc = acc + jnp.sum(jnp.where(dv, d["ruler"], 0))
        return acc

    return compat.shard_map(
        fn, mesh, in_specs=tuple(P(AXES) for _ in range(len(keys) + 2)),
        out_specs=P())


def main():
    mesh = compat.make_mesh(MESH, AXES)
    n = NPE * P_BENCH
    payload, dest, valid, q = chase_queue(n, P_BENCH)
    keys = sorted(payload.keys())
    args = [payload[k] for k in keys] + [dest, valid]
    results = []
    print("name,us_per_call,derived")
    for ind_name, ind, hops in (
            ("direct", None, 1),
            ("grid", IndirectionSpec.grid(AXES), 2)):
        caps = [q] if hops == 1 else [q, 4 * q]
        per = {}
        for packed in (True, False):
            plan = MeshPlan.from_mesh(mesh, AXES, ind, wire_packing=packed)
            coll = introspect.collective_counts(
                build_fn(mesh, plan, caps, keys), *args).get("all_to_all", 0)
            jfn = jax.jit(build_fn(mesh, plan, caps, keys, chain=CHAIN))
            jax.block_until_ready(jfn(*args))
            times = []
            for _ in range(ROUNDS):
                t0 = time.perf_counter()
                jax.block_until_ready(jfn(*args))
                times.append(time.perf_counter() - t0)
            # min over repetitions: robust against the oversubscribed
            # virtual-device scheduling noise of the CPU harness
            us = float(np.min(times)) / CHAIN * 1e6
            label = "packed" if packed else "unpacked"
            per[label] = dict(us_per_round=us, all_to_all=coll)
            print(f"exchange/{ind_name}/{label},{us:.1f},"
                  f"all_to_all={coll};hops={hops}")
        # alpha-beta modeled per-round comm time (§2.6, SuperMUC-like
        # constants — the CPU wall numbers are virtual-PE scheduling
        # noise at small sizes; the model is what carries the trend,
        # same methodology as run.py).
        m = analysis.SUPERMUC
        words = CHASE_WIRE_WORDS * q
        startup = P_BENCH ** (1.0 / hops)
        for label in per:
            per[label]["modeled_us"] = 1e6 * (
                m.alpha * per[label]["all_to_all"] * startup
                + m.beta * words)
        ratio = per["unpacked"]["all_to_all"] / max(
            per["packed"]["all_to_all"], 1)
        speedup = per["unpacked"]["us_per_round"] / max(
            per["packed"]["us_per_round"], 1e-9)
        speedup_model = per["unpacked"]["modeled_us"] / max(
            per["packed"]["modeled_us"], 1e-9)
        print(f"exchange/{ind_name}/summary,"
              f"{per['packed']['us_per_round']:.1f},"
              f"collective_ratio={ratio:.1f};speedup={speedup:.2f};"
              f"modeled_speedup={speedup_model:.2f}")
        results.append(dict(indirection=ind_name, hops=hops, q_per_pe=q,
                            n=n, p=P_BENCH, ratio=ratio, speedup=speedup,
                            modeled_speedup=speedup_model,
                            **{f"{k}_{kk}": vv for k, v in per.items()
                               for kk, vv in v.items()}))

    out_dir = HERE / "results"
    out_dir.mkdir(exist_ok=True)
    (out_dir / "exchange.json").write_text(json.dumps(results, indent=1))
    print(f"# wrote {out_dir / 'exchange.json'}")
    # acceptance guard: packed must save >=1.5x collectives per round,
    # and the alpha-beta model must show lower per-round time.
    assert all(r["ratio"] >= 1.5 for r in results), results
    assert all(r["modeled_speedup"] > 1.0 for r in results), results


if __name__ == "__main__":
    main()
