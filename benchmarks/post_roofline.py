"""Re-derive roofline terms for already-recorded dry-run cells after a
model/constant change (no recompiles — the exact counts are stored)."""
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))
import repro.launch.dryrun as DR  # noqa: E402  (sets XLA flags; fine)
from repro import configs  # noqa: E402
from repro.launch import mesh as mesh_lib  # noqa: E402


def refresh(path):
    rec = json.loads(path.read_text())
    if "skipped" in rec:
        return
    cfg = configs.get_config(rec["arch"])
    chips = rec["chips"]
    flops = rec["cost"]["flops_per_device"]
    bytes_hlo = rec["cost"].get("bytes_per_device_hlo",
                                rec["cost"].get("bytes_per_device", 0.0))
    coll = sum(v for k, v in rec["collectives"].items()
               if k != "collective_ops")
    bm = DR.analytic_memory_bytes(cfg, rec["shape"], rec["kind"], chips,
                                  rec["params"], rec["active_params"])
    t = {"compute_s": flops / mesh_lib.PEAK_FLOPS_BF16,
         "memory_s": bm / mesh_lib.HBM_BW,
         "collective_s": coll / mesh_lib.ICI_BW}
    rec["cost"]["bytes_per_device_hlo"] = bytes_hlo
    rec["cost"]["bytes_per_device_model"] = bm
    rec["cost"].pop("bytes_per_device", None)
    ro = rec["roofline"]
    ro.update(t)
    ro["memory_hlo_s"] = bytes_hlo / mesh_lib.HBM_BW
    ro["bottleneck"] = max(t, key=t.get)
    ro["step_time_bound_s"] = max(t.values())
    ro["mfu_bound"] = ro["model_flops"] / chips / mesh_lib.PEAK_FLOPS_BF16 \
        / max(max(t.values()), 1e-12)
    path.write_text(json.dumps(rec, indent=1))
    print("refreshed", path.name, ro["bottleneck"],
          round(ro["mfu_bound"], 3))


if __name__ == "__main__":
    d = pathlib.Path(__file__).parent / "results" / "dryrun"
    for f in sorted(d.glob("*.json")):
        refresh(f)
