"""Render the §Dry-run / §Roofline markdown tables from the dry-run
artifacts.

  PYTHONPATH=src python benchmarks/roofline_table.py [--mesh single]
"""
import argparse
import json
import pathlib

HERE = pathlib.Path(__file__).parent


def fmt_bytes(b):
    if b >= 1e12:
        return f"{b / 1e12:.1f}TB"
    if b >= 1e9:
        return f"{b / 1e9:.1f}GB"
    return f"{b / 1e6:.0f}MB"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--dir", default=str(HERE / "results" / "dryrun"))
    args = ap.parse_args()
    rows = []
    skips = []
    for f in sorted(pathlib.Path(args.dir).glob(f"*__{args.mesh}.json")):
        rec = json.loads(f.read_text())
        if "skipped" in rec:
            skips.append(rec)
            continue
        rows.append(rec)

    print(f"### Roofline — {rows[0]['mesh'] if rows else args.mesh} mesh, "
          f"per-chip terms (seconds/step)\n")
    print("| arch | shape | step | HBM/dev | compute | memory | collective"
          " | bound | useful | MFU≤ |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        ro = r["roofline"]
        peak = r["memory"].get("peak_bytes_per_device", 0)
        print(f"| {r['arch']} | {r['shape']} | {r['kind']} "
              f"| {fmt_bytes(peak)} "
              f"| {ro['compute_s']:.3g} | {ro['memory_s']:.3g} "
              f"| {ro['collective_s']:.3g} "
              f"| {ro['bottleneck'].replace('_s', '')} "
              f"| {ro['useful_flops_ratio']:.2f} "
              f"| {ro['mfu_bound']:.3f} |")
    if skips:
        print("\nSkipped cells (assignment rule):")
        for s in skips:
            print(f"- {s['arch']} / {s['shape']}: {s['skipped']}")
    print(f"\n{len(rows)} compiled cells, {len(skips)} documented skips.")


if __name__ == "__main__":
    main()
