"""Recovery economics of the level-resumable solver.

Two measurements, in-process on the simshard backend (any p, no
subprocess workers):

1. **resume vs full restart**: prepare a level-boundary checkpoint by
   injecting a preemption after the last descend stage, then time (a) a
   full solve from scratch and (b) a resume from that checkpoint —
   restore cost included. The resume skips prep + every chase level, so
   its wall time is the tail of the schedule; the ratio is what a
   mid-solve fault costs with and without the checkpointable boundary
   state (DESIGN.md §11).
2. **sampled-splitter estimation**: with ``capacity_estimation=True``
   the pre-pass (tuner.estimate_capacities) sizes the mailboxes from an
   instance sample before the first attempt; every one of the paper's 5
   instance families must finish in ``attempts == 1`` at bench scale
   (the acceptance gate), and the measured per-hop slack is recorded.

Results land in benchmarks/results/recovery.json (committed from a
``BENCH_QUICK=1`` run; the flag is recorded in the artifact).
"""
import json
import os
import pathlib
import sys
import tempfile
import time

HERE = pathlib.Path(__file__).parent
RESULTS = HERE / "results"
sys.path.insert(0, str(HERE.parent / "src"))

import numpy as np  # noqa: E402

QUICK = os.environ.get("BENCH_QUICK", "0") == "1"
P = 8 if QUICK else 16
NPE = 1 << 11 if QUICK else 1 << 14
ITERS = 2 if QUICK else 3
SRS_ROUNDS = 2

FAMILIES = [
    ("list_g0.0", lambda n: _list(n, 0.0)),
    ("list_g0.5", lambda n: _list(n, 0.5)),
    ("list_g1.0", lambda n: _list(n, 1.0)),
    ("euler_local", lambda n: _euler(n, True)),
    ("euler_random", lambda n: _euler(n, False)),
]


def _list(n, gamma):
    from repro.core.listrank import instances
    return instances.gen_list(n, gamma=gamma, seed=1)


def _euler(n, locality):
    from repro.core.listrank import instances
    s, r, _ = instances.gen_euler_tour(n // 2 + 1, seed=2,
                                       locality=locality)
    return instances.pad_to_multiple(s, r, P)


def main():
    from repro.core.listrank import (FaultSpec, ListRankConfig,
                                     rank_list_with_stats, sim_mesh, tuner)
    from repro.core.listrank.exchange import MeshPlan
    from repro.runtime.fault_tolerance import (Preempted, SolveSupervisor,
                                               SolveSupervisorConfig)

    mesh = sim_mesh((P,), ("pe",))
    cfg = ListRankConfig(srs_rounds=SRS_ROUNDS, local_contraction=True)
    n = P * NPE
    succ, rank = _list(n, 1.0)

    # ---- 1. resume-from-level-k vs full restart -----------------------
    # warm the stage compile cache, then time steady-state runs.
    rank_list_with_stats(succ, rank, mesh, cfg=cfg)
    t_full = min(_timed(lambda: rank_list_with_stats(succ, rank, mesh,
                                                     cfg=cfg))
                 for _ in range(ITERS))

    with tempfile.TemporaryDirectory() as d:
        prep = SolveSupervisor(SolveSupervisorConfig(ckpt_dir=d))
        try:
            rank_list_with_stats(
                succ, rank, mesh, cfg=cfg, supervisor=prep,
                inject=FaultSpec("preempt", stage="descend",
                                 level=SRS_ROUNDS - 1))
        except Preempted:
            pass
        boundary_idx = prep.latest_meta()["idx"]

        def resume_once():
            # huge cadence: the timed resume restores the prepared
            # checkpoint but writes none of its own, so every iteration
            # resumes from the same boundary.
            sv = SolveSupervisor(SolveSupervisorConfig(ckpt_dir=d,
                                                       ckpt_every=10 ** 9))
            _, _, st = rank_list_with_stats(succ, rank, mesh, cfg=cfg,
                                            supervisor=sv)
            assert st["recovery"]["resumed_from"] == boundary_idx
            return st

        resume_once()  # warm restore path
        t_resume = min(_timed(resume_once) for _ in range(ITERS))

    speedup = t_full / max(t_resume, 1e-9)
    print(f"recovery/resume,p={P},n={n},boundary_idx={boundary_idx},"
          f"full={t_full * 1e3:.1f}ms,resume={t_resume * 1e3:.1f}ms,"
          f"speedup={speedup:.2f}x")

    # ---- 2. estimation pre-pass: attempts == 1 on all families --------
    plan = MeshPlan.from_mesh(mesh, ("pe",))
    m = n // P
    est_cfg = cfg.with_(capacity_estimation=True)
    est_rows = []
    for fam, gen in FAMILIES:
        s_f, r_f = gen(n)
        est = tuner.estimate_capacities(np.asarray(s_f), plan,
                                        s_f.shape[0] // P, est_cfg)
        _, _, st = rank_list_with_stats(s_f, r_f, mesh, cfg=est_cfg)
        est_rows.append({"family": fam, "n": int(s_f.shape[0]),
                         "attempts": st["attempts"],
                         "hop_slack": list(est.hop_slack),
                         "max_frac": list(est.max_frac),
                         "sample_size": est.sample_size})
        print(f"recovery/estimation/{fam},attempts={st['attempts']},"
              f"hop_slack={est.hop_slack[0]:.2f}")

    # gates before touching the committed artifact
    assert speedup > 1.0, \
        f"resume ({t_resume:.3f}s) no faster than full restart ({t_full:.3f}s)"
    bad = [r["family"] for r in est_rows if r["attempts"] != 1]
    assert not bad, f"estimation pre-pass failed to avoid retries on {bad}"

    RESULTS.mkdir(exist_ok=True)
    out = {"quick": QUICK, "p": P, "n_per_pe": NPE,
           "srs_rounds": SRS_ROUNDS,
           "resume": {"boundary_idx": boundary_idx,
                      "t_full_s": t_full, "t_resume_s": t_resume,
                      "speedup": speedup},
           "estimation": est_rows}
    dst = RESULTS / "recovery.json"
    dst.write_text(json.dumps(out, indent=1))
    print(f"# wrote {dst}")


def _timed(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


if __name__ == "__main__":
    main()
