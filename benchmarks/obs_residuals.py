"""Model-vs-measured residual gate: one traced solve per instance
family, per-stage §2.6 predicted-vs-observed table.

Runs a full traced SRS solve (simshard backend, in-process) for every
paper instance family — List(γ∈{0, 0.5, 1}) and both Euler-tour tree
models — and emits the flight recorder's per-stage residual table.
The gate (CI BENCH_QUICK step) is structural: every scheduled stage of
every family must produce a row with a finite measured time and a
prediction, or the bench exits nonzero. Absolute residuals are
reported, not gated — this container measures python-dispatch wall
time on one CPU, so measured/predicted ratios are large by
construction; the artifact records them for trend tracking.

Results land in benchmarks/results/obs_residuals.json
(obs_residuals_quick.json under BENCH_QUICK=1).
"""
import json
import os
import pathlib
import sys

HERE = pathlib.Path(__file__).parent
RESULTS = HERE / "results"
sys.path.insert(0, str(HERE.parent / "src"))

import numpy as np  # noqa: E402

from repro.core.listrank import (ListRankConfig, instances,  # noqa: E402
                                 rank_list_with_stats, sim_mesh)
from repro.core.listrank import resume as resume_lib  # noqa: E402
from repro.obs import (Tracer, format_residual_table,  # noqa: E402
                       residual_rows, residual_summary)
from repro.obs import telemetry as tele_lib  # noqa: E402

QUICK = os.environ.get("BENCH_QUICK", "0") == "1"
P = 8
NPE = 1 << 9 if QUICK else 1 << 13

#: all five families — the gate requires every one, in both modes.
FAMILIES = [
    ("list_g0.0", {"instance": "list", "gamma": 0.0}),
    ("list_g0.5", {"instance": "list", "gamma": 0.5}),
    ("list_g1.0", {"instance": "list", "gamma": 1.0}),
    ("euler_local", {"instance": "euler_local"}),
    ("euler_random", {"instance": "euler_random"}),
]


def make_instance(fam_kw, n):
    if fam_kw["instance"] == "list":
        return instances.gen_list(n, gamma=fam_kw["gamma"], seed=1)
    succ, rank, _ = instances.gen_euler_tour(
        n // 2 + 1, seed=1, locality=fam_kw["instance"] == "euler_local")
    return instances.pad_to_multiple(succ, rank, P)[:2]


def main():
    RESULTS.mkdir(exist_ok=True)
    n = NPE * P
    cfg = ListRankConfig(algorithm="srs", srs_rounds=2,
                         local_contraction=True)
    mesh = sim_mesh(P)
    sched_labels = [st.label for st in resume_lib.schedule_for(
        cfg.with_(algorithm="srs"))]
    records = []
    failures = []
    headroom_report = []
    for fam, fam_kw in FAMILIES:
        succ, rank = make_instance(fam_kw, n)
        tr = Tracer(meta={"name": f"obs_residuals/{fam}", "family": fam})
        _, _, stats = rank_list_with_stats(succ, rank, mesh, cfg=cfg,
                                           seed=1, tracer=tr)
        rows = residual_rows(tr)
        print(format_residual_table(rows, title=f"== {fam} (n={n}, p={P})"))
        summ = residual_summary(rows)
        covered = {r["stage"] for r in rows}
        missing = [lbl for lbl in sched_labels if lbl not in covered]
        ok = (not missing
              and all(np.isfinite(r["measured_s"]) and r["measured_s"] >= 0
                      and np.isfinite(r["predicted_s"]) for r in rows))
        if not ok:
            failures.append((fam, missing))

        # telemetry plane: the same solve with device counters on —
        # every scheduled stage must report finite utilization, and on
        # a first-attempt-clean solve no observed fill may exceed its
        # compiled cap (the headroom report's acceptance gate).
        _, _, tstats = rank_list_with_stats(
            succ, rank, mesh, cfg=cfg.with_(telemetry=True), seed=1)
        tele = tstats.get("telemetry", {})
        stages = tele.get("stages", [])
        tele_missing = [lbl for lbl in sched_labels
                        if lbl not in {s["label"] for s in stages}]
        tele_finite = all(np.isfinite(s["util_max"])
                          and np.isfinite(s["util_mean"]) for s in stages)
        hrows = tele.get("headroom", [])
        worst_fill = max((r["fill_max"] for r in hrows), default=0.0)
        tele_ok = (not tele_missing and tele_finite
                   and (tstats["attempts"] > 1 or worst_fill <= 1.0))
        if not tele_ok:
            failures.append((fam, {"telemetry_missing": tele_missing,
                                   "finite": tele_finite,
                                   "worst_fill": worst_fill}))
        headroom_report.append(
            f"== {fam} (n={n}, p={P}, attempts={tstats['attempts']})\n"
            + tele_lib.format_headroom_table(hrows))
        records.append({"family": fam, "n": n, "p": P, "quick": QUICK,
                        "rows": rows, "summary": summ,
                        "attempts": stats["attempts"], "ok": ok,
                        "telemetry": {"stages": len(stages),
                                      "worst_fill": worst_fill,
                                      "headroom": hrows,
                                      "ok": tele_ok}})
        print(f"obs/{fam},{summ['measured_s'] * 1e6:.1f},"
              f"predicted_s={summ['predicted_s']:.6f};"
              f"stages={summ['stages']};ok={int(ok)};"
              f"tele_worst_fill={worst_fill:.3f};tele_ok={int(tele_ok)}")

    hr_path = RESULTS / ("headroom_quick.txt" if QUICK else "headroom.txt")
    hr_path.write_text("\n\n".join(headroom_report) + "\n")
    print(f"# wrote {hr_path}")

    out = RESULTS / ("obs_residuals_quick.json" if QUICK
                     else "obs_residuals.json")
    out.write_text(json.dumps(records, indent=1))
    print(f"# wrote {out}")
    if failures:
        print(f"RESIDUAL GATE FAILED: {failures}", file=sys.stderr)
        sys.exit(1)
    print(f"# residual gate OK: all {len(FAMILIES)} families produced "
          f"complete per-stage tables and in-cap telemetry headroom")


if __name__ == "__main__":
    main()
