"""Shared helpers for the benchmark harnesses.

One subprocess-worker driver and ONE §2.6 large-p projection, so every
harness (run.py figures, listrank_hillclimb.py, tuning_bench.py)
reports the same "modeled 24576-core s" quantity — computed from the
same counted stats with the same wire-word width.
"""
import json
import pathlib
import subprocess
import sys

HERE = pathlib.Path(__file__).parent


def run_worker(spec: dict, timeout: int = 3600) -> dict:
    """Run one measurement in a fresh subprocess (_worker.py): the
    virtual-device count must be set before jax initializes."""
    cmd = [sys.executable, str(HERE / "_worker.py"), json.dumps(spec)]
    proc = subprocess.run(cmd, capture_output=True, text=True,
                          timeout=timeout)
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(proc.stdout[-400:] + proc.stderr[-1500:])


def modeled_large_p(stats: dict, p_meas: int, p_model: int = 24576,
                    d: int = 2) -> float:
    """α-β projection of counted per-PE loads to ``p_model`` cores.

    Rounds (chase + base-case doubling) each pay the d-hop startup
    α·d·p^(1/d); every counted message crosses d hops at the chase
    wire-format width. Weak scaling keeps both per-PE quantities
    ~constant, so the p=16 counts stand in for the large-p ones.
    """
    from repro.core.listrank import analysis
    from repro.core.listrank.api import CHASE_WIRE_WORDS
    m = analysis.SUPERMUC
    rounds = max((stats.get("rounds", 0) + stats.get("pd_rounds", 0))
                 // p_meas, 1)
    msgs = (stats.get("chase_msgs", 0) + stats.get("pd_msgs", 0)
            + stats.get("fixup_msgs", 0) + stats.get("reversal_msgs", 0))
    words_pe = float(CHASE_WIRE_WORDS) * msgs / p_meas
    return (m.alpha * rounds * d * p_model ** (1.0 / max(d, 1))
            + m.beta * d * words_pe)
