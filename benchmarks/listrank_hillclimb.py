"""§Perf hillclimb on the list-ranking core (the paper's own workload).

Config under test: List(n/p=2^15, gamma=1.0), p=16 virtual PEs (4x4),
SRS + grid indirection — the paper's Fig-3/4 operating point. Measured:
CPU wall time (min of 3) + counted messages/rounds + the alpha-beta
modeled time at p=24576 (SuperMUC constants), since alpha effects do
not show on one CPU.

Iterations follow the hypothesis -> change -> measure -> verdict loop;
results land in benchmarks/results/perf/listrank_hillclimb.json and the
narrative in EXPERIMENTS.md §Perf.
"""
import json
import pathlib
import sys

HERE = pathlib.Path(__file__).parent
sys.path.insert(0, str(HERE.parent / "src"))
sys.path.insert(0, str(HERE))

from _common import modeled_large_p, run_worker  # noqa: E402


BASE = dict(p=16, mesh=(4, 4), n_per_pe=1 << 15, gamma=1.0,
            algorithm="srs", srs_rounds=2, contraction=True,
            indirection="grid", iters=3, ruler_fraction=1 / 32)

STEPS = [
    ("baseline r=n/32 srs2 grid", {}),
    # H1: fewer rulers -> fewer total messages? (model: more rounds)
    ("r=n/64", {"ruler_fraction": 1 / 64}),
    # H2: more rulers -> fewer rounds, bigger base case
    ("r=n/16", {"ruler_fraction": 1 / 16}),
    ("r=n/8", {"ruler_fraction": 1 / 8}),
    # H3: one SRS round (paper: two is better at scale)
    ("srs1", {"srs_rounds": 1}),
    # H4: direct delivery (no indirection) at this p
    ("direct", {"indirection": "direct"}),
    # H5: topology-aware hops
    ("topo", {"indirection": "topo"}),
    # H6: faithful Algorithm 1 (explicit reversal) vs §2.5
    ("reversal", {"avoid_reversal": False}),
]


def main():
    out = []
    for name, kw in STEPS:
        spec = dict(BASE)
        spec.update(kw)
        r = run_worker(spec)
        row = {
            "name": name,
            "wall_s_min": r["wall_s_min"],
            "rounds": r["stats"]["rounds"] // spec["p"],
            "chase_msgs": r["stats"]["chase_msgs"],
            "pd_msgs": r["stats"]["pd_msgs"],
            "fixup_msgs": r["stats"]["fixup_msgs"],
            "sub_size": r["stats"]["sub_size"],
            "reversal_msgs": r["stats"].get("reversal_msgs", 0),
            "modeled_24576_s": modeled_large_p(
                r["stats"], spec["p"],
                d=1 if spec.get("indirection") == "direct" else 2),
        }
        out.append(row)
        print(json.dumps(row))
    dst = HERE / "results" / "perf"
    dst.mkdir(parents=True, exist_ok=True)
    (dst / "listrank_hillclimb.json").write_text(json.dumps(out, indent=1))
    print("wrote", dst / "listrank_hillclimb.json")


if __name__ == "__main__":
    main()
