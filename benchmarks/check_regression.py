"""Perf-regression gate over the benchmark headline metrics.

Compares the *current* headline record — the same per-bench summary
``run.py --summary`` appends to ``benchmarks/results/trajectory.jsonl``,
recomputed in-memory from ``benchmarks/results/*.json`` — against a
committed baseline record, and exits nonzero on a regression:

- **structural counts** (``rows``, ``families_ok``, ``stages``) must
  not shrink: a bench that silently covers fewer cases than the
  baseline is a regression regardless of timing;
- **time metrics** (``wall_s_mean``, ``measured_s``) may not exceed
  ``baseline * (1 + tolerance)``. The default tolerance is generous
  (1.0, i.e. 2x) because this container measures python-dispatch wall
  time on shared CI CPUs; tighten with ``--tolerance`` or
  ``REGRESSION_TOL`` where the runner is quiet.

Baseline selection: ``--baseline FILE`` (a trajectory.jsonl or a single
JSON record), defaulting to the **last** line of
``benchmarks/results/trajectory.jsonl``. Comparison is per result-file
stem, so full-mode and BENCH_QUICK artifacts gate independently
(``graphalg`` vs ``graphalg_quick``) and one baseline record serves
both modes. With no baseline the gate passes and says so — the first
``run.py --summary`` creates it.

CI wiring (see .github/workflows/ci.yml): the BENCH_QUICK smoke steps
rewrite the ``*_quick.json`` artifacts, this gate compares them against
the committed trajectory tail, then ``run.py --summary`` appends the
fresh record so the trajectory actually accrues.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys

HERE = pathlib.Path(__file__).parent
RESULTS = HERE / "results"
sys.path.insert(0, str(HERE))

#: headline keys gated as "bigger is slower" (relative tolerance).
TIME_KEYS = ("wall_s_mean", "measured_s")

#: headline keys gated as "smaller is less coverage" (no tolerance).
COUNT_KEYS = ("rows", "families_ok", "stages")


def load_baseline(path: pathlib.Path) -> dict | None:
    """Last record of a trajectory.jsonl, or a bare record JSON."""
    if not path.exists():
        return None
    text = path.read_text().strip()
    if not text:
        return None
    if path.suffix == ".jsonl":
        last = None
        for line in text.splitlines():
            line = line.strip()
            if not line:
                continue
            try:
                last = json.loads(line)
            except json.JSONDecodeError:
                continue
        return last
    try:
        return json.loads(text)
    except json.JSONDecodeError:
        return None


def current_record() -> dict:
    import run as run_mod
    return run_mod.summarize(write=False)


def compare(baseline: dict, current: dict, tolerance: float,
            only: set[str] | None = None) -> tuple[list[str], list[str]]:
    """Returns (regressions, notes) comparing per-bench headlines."""
    regressions: list[str] = []
    notes: list[str] = []
    base_b = baseline.get("benches", {})
    cur_b = current.get("benches", {})
    for stem in sorted(base_b):
        if only is not None and stem not in only:
            continue
        if stem not in cur_b:
            regressions.append(f"{stem}: result artifact disappeared "
                               f"(was in baseline, missing now)")
            continue
        b, c = base_b[stem], cur_b[stem]
        for key in COUNT_KEYS:
            if key in b and key in c and c[key] < b[key]:
                regressions.append(
                    f"{stem}/{key}: {c[key]} < baseline {b[key]} "
                    f"(coverage shrank)")
        for key in TIME_KEYS:
            if key not in b or key not in c:
                continue
            bv, cv = float(b[key]), float(c[key])
            if bv <= 0:
                continue
            ratio = cv / bv
            limit = 1.0 + tolerance
            line = f"{stem}/{key}: {cv:.4f}s vs baseline {bv:.4f}s " \
                   f"({ratio:.2f}x, limit {limit:.2f}x)"
            if ratio > limit:
                regressions.append(line)
            else:
                notes.append(line)
    for stem in sorted(set(cur_b) - set(base_b)):
        notes.append(f"{stem}: new bench (no baseline yet)")
    return regressions, notes


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline",
                    default=str(RESULTS / "trajectory.jsonl"),
                    help="trajectory.jsonl (last record) or a single "
                         "record JSON to gate against")
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get("REGRESSION_TOL", "1.0")),
                    help="allowed relative slowdown for time metrics "
                         "(1.0 = up to 2x the baseline)")
    ap.add_argument("--bench", action="append", default=None,
                    help="gate only these result-file stems "
                         "(repeatable; default: every stem in the "
                         "baseline)")
    ns = ap.parse_args()

    baseline = load_baseline(pathlib.Path(ns.baseline))
    if baseline is None:
        print(f"# no baseline record at {ns.baseline} — gate passes "
              f"vacuously; run `python benchmarks/run.py --summary` to "
              f"create one")
        return 0
    current = current_record()
    only = set(ns.bench) if ns.bench else None
    regressions, notes = compare(baseline, current, ns.tolerance, only)
    for line in notes:
        print(f"  ok  {line}")
    if regressions:
        print(f"PERF REGRESSION GATE FAILED "
              f"(vs {baseline.get('git_rev', '?')}, "
              f"tolerance {ns.tolerance:g}):", file=sys.stderr)
        for line in regressions:
            print(f"  REGRESSION {line}", file=sys.stderr)
        return 1
    print(f"# regression gate OK: {len(notes)} metrics within "
          f"tolerance {ns.tolerance:g} of baseline "
          f"{baseline.get('git_rev', '?')}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
