"""Benchmark subprocess worker: runs one list-ranking configuration on
``p`` virtual devices and prints a JSON result line.

Separate process per measurement because the device count must be set
before jax initializes (and compile memory is returned to the OS).

argv: a single JSON object, e.g.
  {"p": 8, "mesh": [2,4], "n_per_pe": 16384, "gamma": 1.0,
   "algorithm": "srs", "srs_rounds": 2, "contraction": true,
   "indirection": "direct|grid|topo", "iters": 3, "instance": "list"}
"""
import json
import os
import sys

spec = json.loads(sys.argv[1])
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={spec['p']}")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time  # noqa: E402

import numpy as np  # noqa: E402
import jax  # noqa: E402

from repro import compat  # noqa: E402
from repro.core.listrank import (IndirectionSpec, ListRankConfig,  # noqa
                                 analysis, instances, rank_list_with_stats)
from repro.obs import json_safe_stats  # noqa: E402

MACHINES = {"supermuc": analysis.SUPERMUC, "tpu": analysis.TPU_V5E_ICI,
            "intra": analysis.INTRA_NODE}


def main():
    rows, cols = spec.get("mesh") or (1, spec["p"])
    mesh = compat.make_mesh((rows, cols), ("row", "col"))
    n = spec["n_per_pe"] * spec["p"]
    inst = spec.get("instance", "list")
    if inst == "list":
        succ, rank = instances.gen_list(n, gamma=spec.get("gamma", 1.0),
                                        seed=spec.get("seed", 1))
    elif inst == "euler_local":
        succ, rank, _ = instances.gen_euler_tour(n // 2 + 1, seed=1,
                                                 locality=True)
        succ, rank = instances.pad_to_multiple(succ, rank, spec["p"])
    elif inst == "euler_random":
        succ, rank, _ = instances.gen_euler_tour(n // 2 + 1, seed=1,
                                                 locality=False)
        succ, rank = instances.pad_to_multiple(succ, rank, spec["p"])
    else:
        raise ValueError(inst)

    delta = instances.locality_fraction(succ, spec["p"])
    cfg = ListRankConfig(
        algorithm=spec.get("algorithm", "srs"),
        srs_rounds=spec.get("srs_rounds", 2),
        local_contraction=spec.get("contraction", True),
        ruler_fraction=spec.get("ruler_fraction", 1 / 32),
        machine=MACHINES[spec.get("machine", "supermuc")],
        avoid_reversal=spec.get("avoid_reversal", True))
    ind = {"direct": None,
           "grid": IndirectionSpec.grid(("row", "col")),
           "topo": IndirectionSpec.topology(("col",), ("row",))}[
               spec.get("indirection", "direct")]

    # warmup (compile) + timed iterations, paper methodology: discard
    # the first run, report mean of the rest
    times = []
    stats = None
    for it in range(spec.get("iters", 3) + 1):
        t0 = time.time()
        s, r, stats = rank_list_with_stats(succ, rank, mesh, cfg=cfg,
                                           indirection=ind,
                                           seed=spec.get("seed", 1))
        jax.block_until_ready(s)
        dt = time.time() - t0
        if it > 0:
            times.append(dt)

    out = {
        "wall_s_mean": float(np.mean(times)),
        "wall_s_min": float(np.min(times)),
        "wall_s_max": float(np.max(times)),
        "delta_locality": delta,
        "n": n,
        # stats carry int counters plus strings (scales_log), tuples
        # (stage_log) and nested dicts (recovery) — the obs layer owns
        # the canonical JSON-safe conversion
        "stats": json_safe_stats(stats),
    }
    print("RESULT " + json.dumps(out))


if __name__ == "__main__":
    main()
