"""simshard benchmark: how far can virtual p push on one host?

For each virtual PE count the harness runs the full solver on the
simshard backend (one process, one device, no XLA_FLAGS subprocesses),
and records:

  - compile + first-solve wall time and steady-state solve wall time
    (the emulation's practical limit is compile time and memory, both
    growing with p),
  - the traced collective counts via the simulated-collective markers
    (must stay the mesh program's counts — the coalescing invariant at
    every p),
  - solver round/message counters, which feed the same §2.6 modeled
    time as every other bench.

Usage: python benchmarks/simshard_bench.py   (BENCH_QUICK=1 for smoke).
Full mode writes benchmarks/results/simshard.json (committed); quick
mode writes simshard_quick.json (NOT committed).

Measured practical limit on this CPU container: p=512 cold-compiles in
~4 minutes; p=1024 blows past 25 minutes of XLA compile (the batched
mailbox transposes scale with p^2 x cap), so the committed sweep tops
out at 512 — that IS the answer to "how far can virtual p push on one
host" today, and the number to beat when attacking compile time.
"""
from __future__ import annotations

import json
import os
import pathlib
import sys
import time

HERE = pathlib.Path(__file__).parent
sys.path.insert(0, str(HERE.parent / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core.listrank import (ListRankConfig, instances,  # noqa: E402
                                 introspect, rank_list_seq,
                                 rank_list_with_stats, sim_mesh)
from repro.core.listrank import api as api_lib  # noqa: E402
from repro.core.listrank import transport as transport_lib  # noqa: E402
from repro.core.listrank.exchange import MeshPlan  # noqa: E402

QUICK = os.environ.get("BENCH_QUICK", "0") == "1"
P_SIZES = (8, 64) if QUICK else (8, 64, 256, 512)
N_PER_PE = 256 if QUICK else 1024
RESULTS = HERE / "results"


def _trace_counts(p: int, n: int, cfg: ListRankConfig) -> dict:
    mesh = sim_mesh(p)
    plan = MeshPlan.from_mesh(mesh, ("pe",))
    m = n // p
    specs = api_lib.build_specs(cfg, plan, m, n, term_bound=1)
    import functools
    fn = functools.partial(api_lib._solve_sharded, plan=plan, cfg=cfg,
                           specs=specs, m=m)
    spec = P(("pe",))
    runner = transport_lib.device_run(mesh, ("pe",), fn,
                                      in_specs=(spec, spec, P()),
                                      out_specs=(spec, spec, P()))
    args = (jnp.zeros(n, jnp.int32), jnp.zeros(n, jnp.int32), jnp.int32(0))
    return introspect.collective_counts(runner, *args)


def bench_p(p: int) -> dict:
    n = p * N_PER_PE
    cfg = ListRankConfig(srs_rounds=1, local_contraction=True)
    succ, rank = instances.gen_list(n, gamma=1.0, seed=1)
    mesh = sim_mesh(p)

    t0 = time.perf_counter()
    s, r, stats = rank_list_with_stats(succ, rank, mesh, cfg=cfg,
                                       term_bound=1)
    cold_s = time.perf_counter() - t0
    s_ref, r_ref = rank_list_seq(succ, rank)
    ok = (np.array_equal(np.asarray(s), s_ref)
          and np.array_equal(np.asarray(r), r_ref))

    t0 = time.perf_counter()
    rank_list_with_stats(succ, rank, mesh, cfg=cfg, term_bound=1)
    warm_s = time.perf_counter() - t0

    counts = _trace_counts(p, n, cfg)
    row = {
        "p": p, "n": n, "n_per_pe": N_PER_PE, "correct": bool(ok),
        "cold_wall_s": cold_s, "warm_wall_s": warm_s,
        "collectives": counts,
        "rounds": stats["rounds"] // p,
        "chase_msgs": stats["chase_msgs"],
        "attempts": stats["attempts"],
    }
    print(f"simshard/p{p},{warm_s * 1e6:.1f},"
          f"cold_s={cold_s:.2f};a2a={counts.get('all_to_all', 0)};"
          f"rounds={row['rounds']};ok={ok}")
    return row


def main():
    print("name,us_per_call,derived")
    rows = [bench_p(p) for p in P_SIZES]
    RESULTS.mkdir(exist_ok=True)
    out = RESULTS / ("simshard_quick.json" if QUICK else "simshard.json")
    out.write_text(json.dumps(rows, indent=1))
    print(f"# wrote {out}")
    if any(not r["correct"] for r in rows):
        sys.exit(1)


if __name__ == "__main__":
    main()
