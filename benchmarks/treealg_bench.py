"""treealg benchmark: tree statistics per tree family + the batched
front door's invocation economics.

Per tree family (GNM-BFS-like random attachment, RGG2D-BFS-like
windowed attachment — the paper's two Euler-tour models):

  * device tour construction + full ``tree_stats`` wall time,
  * the tour's locality fraction delta (EXPERIMENTS.md table), and
  * the **modeled 24576-core time** projected from the counted
    rounds/messages with SuperMUC alpha-beta constants (`_common`),
    the same methodology as every other harness here.

Batch scenario (the serving story): B same-size trees solved one by
one versus through ``solve_forest`` (ONE tour build + ONE batched mesh
solve). The batched path must cost a single solver invocation and beat
the sequential wall time.

Output: ``name,us_per_call,derived`` CSV lines (harness contract) and
benchmarks/results/treealg.json. Standalone:

  BENCH_QUICK=1 python benchmarks/treealg_bench.py
"""
from __future__ import annotations

import json
import os
import pathlib
import sys
import time

HERE = pathlib.Path(__file__).parent
QUICK = os.environ.get("BENCH_QUICK", "0") == "1"
P_BENCH = 4 if QUICK else 8
MESH = (2, 2) if QUICK else (2, 4)
N_NODES = 1 << 10 if QUICK else 1 << 14
B_TREES = 6 if QUICK else 8
N_SMALL = 200 if QUICK else 400
ITERS = 1 if QUICK else 3
P_MODEL = 24576

os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={P_BENCH}")
sys.path.insert(0, str(HERE.parent / "src"))
sys.path.insert(0, str(HERE))

import numpy as np  # noqa: E402
import jax  # noqa: E402

from _common import modeled_large_p  # noqa: E402
from repro import compat  # noqa: E402
from repro.core import treealg  # noqa: E402
from repro.core.listrank import ListRankConfig, instances  # noqa: E402

AXES = ("row", "col")
FAMILIES = [("gnm", False), ("rgg2d", True)]


def make_parent(n, seed, locality):
    return instances.gen_tree_parents(n, seed=seed, locality=locality)


def timed(fn, iters):
    fn()  # warmup / compile
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.min(times))


def main():
    mesh = compat.make_mesh(MESH, AXES)
    cfg = ListRankConfig(srs_rounds=2, local_contraction=True)
    results = {"quick": QUICK, "p": P_BENCH, "n_nodes": N_NODES,
               "p_model": P_MODEL, "families": [], "batch": {}}
    print("name,us_per_call,derived")

    for fam, locality in FAMILIES:
        parent = make_parent(N_NODES, seed=1, locality=locality)
        succ_d, _, _ = treealg.build_tour(parent, mesh, cfg=cfg)
        succ_np = np.asarray(jax.device_get(succ_d))
        delta = instances.locality_fraction(succ_np, P_BENCH)
        wall_tour = timed(
            lambda: jax.block_until_ready(
                treealg.build_tour(parent, mesh, cfg=cfg)[0]), ITERS)
        st_holder = {}

        def solve():
            st_holder["st"] = treealg.tree_stats(parent, mesh, cfg=cfg)

        wall_stats = timed(solve, ITERS)
        stats = st_holder["st"].stats
        modeled = modeled_large_p(stats, P_BENCH, P_MODEL, d=1)
        row = dict(family=fam, n_nodes=N_NODES, delta_locality=delta,
                   wall_tour_s=wall_tour, wall_stats_s=wall_stats,
                   rounds=stats["rounds"] // P_BENCH,
                   pd_rounds=stats["pd_rounds"] // P_BENCH,
                   chase_msgs=stats["chase_msgs"],
                   attempts=stats["attempts"],
                   modeled_24576_s=modeled)
        results["families"].append(row)
        print(f"treealg/{fam}/tree_stats,{wall_stats * 1e6:.1f},"
              f"modeled_s={modeled:.5f};delta={delta:.2f};"
              f"rounds={row['rounds']}")

    # batched front door vs one-by-one solves (same-size trees, so the
    # sequential baseline amortizes its compile and the comparison is
    # pure per-invocation cost + rounds)
    parents = [make_parent(N_SMALL, seed=10 + b, locality=bool(b % 2))
               for b in range(B_TREES)]

    def seq():
        for q in parents:
            treealg.tree_stats(q, mesh, cfg=cfg)

    def batched():
        treealg.solve_forest(parents, mesh, cfg=cfg)

    wall_seq = timed(seq, ITERS)
    wall_batch = timed(batched, ITERS)
    speedup = wall_seq / max(wall_batch, 1e-9)
    results["batch"] = dict(n_trees=B_TREES, n_small=N_SMALL,
                            wall_seq_s=wall_seq, wall_batch_s=wall_batch,
                            speedup=speedup, batched_invocations=1,
                            seq_invocations=B_TREES)
    print(f"treealg/batch/solve_forest,{wall_batch * 1e6:.1f},"
          f"speedup={speedup:.2f};trees={B_TREES};invocations=1_vs_"
          f"{B_TREES}")

    out_dir = HERE / "results"
    out_dir.mkdir(exist_ok=True)
    dst = out_dir / ("treealg_quick.json" if QUICK else "treealg.json")
    dst.write_text(json.dumps(results, indent=1))
    print(f"# wrote {dst}")

    # acceptance guards: the RGG2D-like tour must show the locality the
    # instance model promises, every solve must land on attempt 1, and
    # batching B trees must beat B sequential solves.
    fams = {r["family"]: r for r in results["families"]}
    assert fams["rgg2d"]["delta_locality"] > fams["gnm"]["delta_locality"], \
        "RGG2D-like tour lost its locality edge"
    assert all(r["attempts"] == 1 for r in results["families"]), \
        "capacity retries fired on a default config"
    assert speedup > 1.0, \
        f"batched front door slower than sequential ({speedup:.2f}x)"


if __name__ == "__main__":
    main()
