"""graphalg benchmark: connectivity + spanning-forest statistics per
instance family, and the end-to-end graph_stats pipeline economics.

Per edge-list family (GNM-like random, RGG2D-like windowed — with and
without multiple components):

  * connected_components and full graph_stats wall time,
  * the hooking-round count and message volume (the §2.6 quantities
    for the *second* communication pattern the repo now exercises),
  * the edge list's PE-crossing fraction (EXPERIMENTS.md connectivity
    table) and the **modeled 24576-core time** projected from counted
    rounds/messages with SuperMUC alpha-beta constants (`_common`),
  * the traced collective footprint of the one-program pipeline
    (count must be instance-independent; recorded in the artifact).

Output: ``name,us_per_call,derived`` CSV lines (harness contract) and
benchmarks/results/graphalg.json. Standalone:

  BENCH_QUICK=1 python benchmarks/graphalg_bench.py
"""
from __future__ import annotations

import json
import os
import pathlib
import sys
import time

HERE = pathlib.Path(__file__).parent
QUICK = os.environ.get("BENCH_QUICK", "0") == "1"
P_BENCH = 4 if QUICK else 8
MESH = (2, 2) if QUICK else (2, 4)
N_NODES = 1 << 9 if QUICK else 1 << 12
EDGE_FACTOR = 2
ITERS = 1 if QUICK else 3
P_MODEL = 24576

os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={P_BENCH}")
sys.path.insert(0, str(HERE.parent / "src"))
sys.path.insert(0, str(HERE))

import numpy as np  # noqa: E402
import jax  # noqa: E402

from _common import modeled_large_p  # noqa: E402
from repro import compat  # noqa: E402
from repro.core import graphalg  # noqa: E402
from repro.core.listrank import ListRankConfig, instances  # noqa: E402

AXES = ("row", "col")
FAMILIES = [
    ("gnm", dict(locality=False)),
    ("rgg2d", dict(locality=True)),
    ("gnm_multi", dict(locality=False, num_components=8)),
    ("rgg2d_multi", dict(locality=True, num_components=8)),
]


def timed(fn, iters):
    fn()  # warmup / compile
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.min(times))


def cross_fraction(edges, p, n):
    m = n // p
    return float(np.mean(edges[:, 0] // m != edges[:, 1] // m))


def main():
    mesh = compat.make_mesh(MESH, AXES)
    cfg = ListRankConfig(srs_rounds=1, local_contraction=True)
    results = {"quick": QUICK, "p": P_BENCH, "n_nodes": N_NODES,
               "edge_factor": EDGE_FACTOR, "p_model": P_MODEL,
               "families": []}
    print("name,us_per_call,derived")

    footprints = set()
    for fam, kw in FAMILIES:
        n = N_NODES
        e = EDGE_FACTOR * n
        edges = instances.gen_graph_edges(n, e, seed=1, **kw)
        delta = cross_fraction(edges, P_BENCH, n)

        wall_cc = timed(lambda: graphalg.connected_components(
            edges, n, mesh, cfg=cfg), ITERS)
        holder = {}

        def solve():
            holder["gs"] = graphalg.graph_stats(edges, n, mesh, cfg=cfg)

        wall_stats = timed(solve, ITERS)
        st = holder["gs"].stats
        # fold the graph pipeline's own traffic into the §2.6
        # projection: cc_msgs/tour_msgs are globally-summed like
        # chase_msgs, and each hooking round costs ~8 comm legs (label
        # gather 2, proposals 1, confirmation 1, ~2 shortcut gathers =
        # 4) plus ~6 legs of tour build + finalization per run —
        # rounds in modeled_large_p are per-PE, hence the P_BENCH
        # factor on the replicated cc_rounds counter.
        aug = dict(st)
        aug["rounds"] = st["rounds"] + \
            (8 * st["cc_rounds"] + 6) * P_BENCH
        aug["chase_msgs"] = st["chase_msgs"] + st["cc_msgs"] \
            + st["tour_msgs"]
        modeled = modeled_large_p(aug, P_BENCH, P_MODEL, d=1)
        fp = graphalg.pipeline_collective_footprint(edges, n, mesh, cfg=cfg)
        footprints.add(fp["all_to_all"][0])
        row = dict(
            family=fam, n_nodes=n, n_edges=e,
            cross_fraction=delta,
            n_components=int(holder["gs"].n_components),
            wall_cc_s=wall_cc, wall_stats_s=wall_stats,
            cc_rounds=st["cc_rounds"], cc_msgs=st["cc_msgs"],
            solve_rounds=st["rounds"] // P_BENCH,
            attempts=st["attempts"],
            a2a_count=fp["all_to_all"][0],
            a2a_bytes=fp["all_to_all"][1],
            modeled_24576_s=modeled)
        results["families"].append(row)
        print(f"graphalg/{fam}/cc,{wall_cc * 1e6:.1f},"
              f"rounds={st['cc_rounds']};cross={delta:.2f}")
        print(f"graphalg/{fam}/graph_stats,{wall_stats * 1e6:.1f},"
              f"modeled_s={modeled:.5f};a2a={fp['all_to_all'][0]};"
              f"comps={row['n_components']}")

    out_dir = HERE / "results"
    out_dir.mkdir(exist_ok=True)
    dst = out_dir / ("graphalg_quick.json" if QUICK else "graphalg.json")
    dst.write_text(json.dumps(results, indent=1))
    print(f"# wrote {dst}")

    # acceptance guards: the RGG2D-like families must show the locality
    # the instance model promises, every pipeline must land on attempt
    # 1 with its capacities as derived, and the one-program collective
    # count must be instance-independent (the coalescing invariant).
    fams = {r["family"]: r for r in results["families"]}
    assert fams["rgg2d"]["cross_fraction"] < fams["gnm"]["cross_fraction"], \
        "RGG2D-like edges lost their locality edge"
    assert all(r["attempts"] == 1 for r in results["families"]), \
        "capacity retries fired on a default config"
    assert len(footprints) == 1, \
        f"collective count varies across instances: {footprints}"


if __name__ == "__main__":
    main()
