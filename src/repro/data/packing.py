"""Sequence packing with distributed list ranking (DESIGN.md §3.1).

Packing concatenates variable-length documents into fixed-length rows.
Each document's tokens form a chain of *segments* scattered across
packed rows (and across data shards). Computing per-token metadata —
position-in-document, tokens-remaining (needed for causal masking,
document-boundary resets, and span-corruption objectives) — is exactly
*weighted list ranking* on the segment chains:

  element  = one packed segment,
  succ     = the document's next segment (wherever it landed),
  weight   = segment length,
  rank     = tokens of this document after this segment  (dist-to-
             terminal), and the terminal id identifies the document's
             final segment — i.e. the document itself.

On a real pod the segment chains live sharded exactly like the rows
they sit in, so this runs as a ``rank_list`` call over the data mesh
(γ here = fraction of consecutive segments co-located on a shard — the
paper's locality parameter, controlled by the packer's shard-local
greedy fill). This module provides the instance builder, the
distributed path, and a numpy oracle.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.listrank import rank_list_with_stats, sequential as seq_lib


@dataclasses.dataclass
class Packed:
    """rows: (R, L) token rows; doc_id / pos_in_doc / remaining: (R, L)."""
    rows: np.ndarray
    segment_doc: np.ndarray     # (n_segments,) document of each segment
    segment_row: np.ndarray     # (n_segments,) row holding the segment
    segment_off: np.ndarray     # (n_segments,) offset within the row
    segment_len: np.ndarray
    succ: np.ndarray            # the list-ranking instance over segments
    weight: np.ndarray


def pack_documents(docs: list[np.ndarray], row_len: int,
                   pad_id: int = 0) -> Packed:
    """Greedy first-fit packing, splitting docs across rows when needed.

    Returns the packed rows plus the segment-chain list-ranking
    instance (succ, weight) over segments in row-major order.
    """
    rows: list[list[int]] = [[]]
    seg_doc, seg_row, seg_off, seg_len = [], [], [], []
    doc_segments: list[list[int]] = []
    for d, doc in enumerate(docs):
        remaining = list(map(int, doc))
        my_segs = []
        while remaining:
            if len(rows[-1]) >= row_len:
                rows.append([])
            space = row_len - len(rows[-1])
            take = remaining[:space]
            remaining = remaining[space:]
            my_segs.append(len(seg_doc))
            seg_doc.append(d)
            seg_row.append(len(rows) - 1)
            seg_off.append(len(rows[-1]))
            seg_len.append(len(take))
            rows[-1].extend(take)
        doc_segments.append(my_segs)
    mat = np.full((len(rows), row_len), pad_id, dtype=np.int32)
    for r, row in enumerate(rows):
        mat[r, :len(row)] = row

    n = len(seg_doc)
    succ = np.arange(n, dtype=np.int32)
    weight = np.zeros(n, dtype=np.int32)
    for segs in doc_segments:
        for a, b in zip(segs[:-1], segs[1:]):
            succ[a] = b
            weight[a] = seg_len[b]  # dist-to-terminal counts tokens after
    return Packed(rows=mat, segment_doc=np.asarray(seg_doc),
                  segment_row=np.asarray(seg_row),
                  segment_off=np.asarray(seg_off),
                  segment_len=np.asarray(seg_len),
                  succ=succ, weight=weight)


def segment_metadata(packed: Packed, mesh=None, **rank_kw):
    """Per-segment (final_segment, tokens_after) via list ranking.

    With ``mesh`` given, runs the paper's distributed algorithm over the
    mesh; otherwise the numpy oracle. Returns (term_seg, tokens_after).
    """
    n = packed.succ.shape[0]
    if mesh is not None:
        p = 1
        for s in mesh.devices.shape:
            p *= s
        pad = (-n) % p
        succ = np.concatenate([packed.succ,
                               np.arange(n, n + pad, dtype=np.int32)])
        w = np.concatenate([packed.weight, np.zeros(pad, np.int32)])
        sf, rf, _ = rank_list_with_stats(succ, w, mesh, **rank_kw)
        return np.asarray(sf)[:n], np.asarray(rf)[:n]
    return seq_lib.rank_list_seq(packed.succ, packed.weight)


def token_metadata(packed: Packed, term_seg, tokens_after):
    """Expand segment results to per-token (doc_id, pos_in_doc,
    remaining_after_token) arrays of the packed shape."""
    r, l = packed.rows.shape
    doc_id = np.full((r, l), -1, np.int64)
    pos = np.zeros((r, l), np.int64)
    rem = np.zeros((r, l), np.int64)
    # tokens borne before each segment = doc_len - seg_len - tokens_after
    doc_len = np.zeros(packed.segment_doc.max() + 1 if packed.segment_doc.size else 1,
                       np.int64)
    np.add.at(doc_len, packed.segment_doc, packed.segment_len)
    for s in range(packed.succ.shape[0]):
        row, off, ln = packed.segment_row[s], packed.segment_off[s], packed.segment_len[s]
        d = packed.segment_doc[s]
        before = doc_len[d] - tokens_after[s] - ln
        ar = np.arange(ln)
        doc_id[row, off:off + ln] = d
        pos[row, off:off + ln] = before + ar
        rem[row, off:off + ln] = doc_len[d] - (before + ar) - 1
    return doc_id, pos, rem
