from repro.data import packing, pipeline

__all__ = ["packing", "pipeline"]
