"""Deterministic, shardable synthetic data pipeline.

Produces packed LM batches (tokens/labels + document metadata from the
list-ranking packer) with a stateless index->batch mapping, so any step
can be regenerated after restart (the checkpoint stores only the step).

The token stream is a seeded PRNG "corpus" of documents with log-normal
lengths — enough structure for loss-goes-down end-to-end runs without
shipping a dataset.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.data import packing


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    mean_doc_len: float = 350.0
    pack: bool = True


def _docs_for_batch(cfg: DataConfig, step: int) -> list[np.ndarray]:
    rng = np.random.default_rng((cfg.seed, step))
    need = cfg.seq_len * cfg.global_batch
    docs, total = [], 0
    while total < need:
        ln = int(np.clip(rng.lognormal(np.log(cfg.mean_doc_len), 0.7),
                         16, 4 * cfg.mean_doc_len))
        ln = min(ln, need - total) or 1
        # skewed unigram distribution, zipf-ish
        d = (rng.zipf(1.3, size=ln) % (cfg.vocab_size - 2)) + 2
        docs.append(d.astype(np.int32))
        total += ln
    return docs


def global_batch(cfg: DataConfig, step: int, mesh=None):
    """Build batch ``step`` (numpy, host-side). Deterministic in
    (seed, step). Returns dict with tokens/labels (+doc metadata)."""
    docs = _docs_for_batch(cfg, step)
    if cfg.pack:
        packed = packing.pack_documents(docs, cfg.seq_len)
        term, after = packing.segment_metadata(packed, mesh=None)
        doc_id, pos, rem = packing.token_metadata(packed, term, after)
        rows = packed.rows[:cfg.global_batch]
        doc_id = doc_id[:cfg.global_batch]
        if rows.shape[0] < cfg.global_batch:
            padr = cfg.global_batch - rows.shape[0]
            rows = np.pad(rows, ((0, padr), (0, 0)))
            doc_id = np.pad(doc_id, ((0, padr), (0, 0)), constant_values=-1)
        labels = np.where(doc_id >= 0, rows, -100).astype(np.int32)
        return {"tokens": rows.astype(np.int32), "labels": labels}
    flat = np.concatenate(docs)[:cfg.seq_len * cfg.global_batch]
    rows = flat.reshape(cfg.global_batch, cfg.seq_len).astype(np.int32)
    return {"tokens": rows, "labels": rows.copy()}


def device_batch(cfg: DataConfig, step: int, mesh, shardings):
    """Place the global batch on the mesh per the given shardings."""
    host = global_batch(cfg, step)
    return {k: jax.device_put(v, shardings[k]) for k, v in host.items()}
