from repro.checkpoint.checkpointer import Checkpointer, CheckpointWriteError

__all__ = ["Checkpointer", "CheckpointWriteError"]
