"""Checkpointing: atomic, keep-k, async-capable, elastic restore.

Format: one .npz per checkpoint step holding the flattened train state
(params / optimizer / step / data cursor), plus a JSON manifest with
the tree structure and logical axes. Restore re-places every leaf with
the shardings of the *current* mesh — restarting on a different mesh
shape (elastic up/down-scaling) re-shards transparently, because leaves
are stored as full (host-gathered) arrays.

On a real multi-host pod the .npz writer would be replaced by a
per-shard OCDBT/tensorstore writer; the manifest/atomic-rename/keep-k/
async logic — the part this module owns — is identical.
"""
from __future__ import annotations

import concurrent.futures as futures
import json
import pathlib
import shutil
import time

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]
    return keys, [leaf for _, leaf in flat], treedef


class CheckpointWriteError(RuntimeError):
    """A background checkpoint write failed; carries the failing step."""

    def __init__(self, step: int, cause: BaseException):
        super().__init__(
            f"background checkpoint write for step {step} failed: "
            f"{cause!r}")
        self.step = step
        self.__cause__ = cause


class Checkpointer:
    def __init__(self, directory, keep: int = 3, async_save: bool = True):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pool = futures.ThreadPoolExecutor(1) if async_save else None
        self._pending: futures.Future | None = None
        self._pending_step: int | None = None

    # ------------------------------------------------------------- save
    def save(self, step: int, state, blocking: bool = False, meta=None):
        """Snapshot ``state`` at ``step``. Device->host copy happens
        synchronously (consistent snapshot); serialization + fsync run
        on the background thread unless blocking. ``meta`` (a JSON-able
        dict) is stored in the step's manifest. A failure of the
        *previous* background write surfaces here (or at :meth:`wait`)
        as :class:`CheckpointWriteError` naming the failed step."""
        keys, leaves, _ = _flatten(state)
        host = [np.asarray(jax.device_get(x)) for x in leaves]
        self.wait()  # one in flight at a time; surfaces prior failures
        if self._pool is not None and not blocking:
            self._pending_step = step
            self._pending = self._pool.submit(self._write, step, keys, host,
                                              meta)
        else:
            self._write(step, keys, host, meta)

    def wait(self):
        if self._pending is not None:
            pending, step = self._pending, self._pending_step
            self._pending, self._pending_step = None, None
            try:
                pending.result()
            except Exception as e:
                raise CheckpointWriteError(step, e) from e

    def _write(self, step, keys, host, meta=None):
        tmp = self.dir / f".tmp-{step}-{time.time_ns()}"
        tmp.mkdir()
        np.savez(tmp / "state.npz", **{k: v for k, v in zip(keys, host)})
        (tmp / "manifest.json").write_text(json.dumps(
            {"step": step, "keys": keys, "time": time.time(),
             "meta": meta}))
        final = self.dir / f"step_{step:08d}"
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic publish
        self._gc(protect=step)

    def _gc(self, protect: int | None = None):
        """Keep the newest ``keep`` checkpoints — but never delete the
        step just written (``protect``): publishing an out-of-order step
        must not gc the checkpoint the caller believes now exists."""
        keep_names = {f"step_{protect:08d}"} if protect is not None else set()
        ckpts = sorted(self.dir.glob("step_*"))
        for old in ckpts[:-self.keep]:
            if old.name not in keep_names:
                shutil.rmtree(old, ignore_errors=True)

    # ---------------------------------------------------------- restore
    def latest_step(self) -> int | None:
        ckpts = sorted(self.dir.glob("step_*"))
        return int(ckpts[-1].name.split("_")[1]) if ckpts else None

    def manifest(self, step: int | None = None) -> dict:
        """The manifest dict of ``step`` (latest when None) — includes
        the ``meta`` stored at save time. Lets a restorer read the
        layout parameters before it can build the ``like`` tree."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = self.dir / f"step_{step:08d}" / "manifest.json"
        return json.loads(path.read_text())

    def restore(self, step: int | None, like, shardings=None):
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs), placing leaves with ``shardings`` (elastic:
        any mesh works since leaves are stored unsharded)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = self.dir / f"step_{step:08d}"
        data = np.load(path / "state.npz")
        keys, leaves, treedef = _flatten(like)
        sh_flat = (jax.tree.leaves(shardings) if shardings is not None
                   else [None] * len(leaves))
        out = []
        for k, leaf, sh in zip(keys, leaves, sh_flat):
            arr = data[k]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"shape mismatch for {k}: "
                                 f"{arr.shape} vs {leaf.shape}")
            arr = arr.astype(leaf.dtype)
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out), step
