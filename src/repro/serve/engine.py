"""Batched serving engine: continuous batching over a fixed slot pool.

The engine owns a (slots, max_seq) KV/SSM cache. Requests queue up;
free slots are prefilled (one jitted prefill per admission, right-
padded to a bucket length), then all active slots advance together
through a single fused ``decode_step``. Finished slots (EOS or length
limit) free immediately and the next queued request is admitted —
continuous batching, the serving-side analogue of ruler spawning: keep
the number of in-flight sequences ("waves") constant by replacing every
finished one.

Greedy or temperature sampling; per-slot position bookkeeping supports
heterogeneous prompt lengths.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M


@dataclasses.dataclass
class ServeConfig:
    slots: int = 8
    max_seq: int = 1024
    eos_id: int = 1
    temperature: float = 0.0
    prefill_bucket: int = 128
    max_new_tokens: int = 64


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray
    max_new_tokens: int | None = None


class ServingEngine:
    def __init__(self, params, cfg: M.ModelConfig, scfg: ServeConfig):
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self.cache = M.init_cache(cfg, scfg.slots, scfg.max_seq)
        self.pos = np.zeros(scfg.slots, np.int32)          # next position
        self.active = np.zeros(scfg.slots, bool)
        self.last_tok = np.zeros(scfg.slots, np.int32)
        self.budget = np.zeros(scfg.slots, np.int32)
        self.uid = [-1] * scfg.slots
        self.out: dict[int, list[int]] = {}
        self.queue: deque[Request] = deque()
        self._decode = jax.jit(functools.partial(self._decode_impl, cfg=cfg))
        self._prefill = {}

    # -------------------------------------------------------- internals
    def _decode_impl(self, params, toks, pos_arr, cache, key, cfg):
        """Advance every slot one token (positions vary per slot)."""
        b = toks.shape[0]
        x = M.L.embed(params["embed"], toks[:, None], cfg)
        positions = pos_arr[:, None]
        x, _, new_cache = M._run_stack(
            params["layers"], x, cfg, positions=positions, causal=True,
            local_flags=cfg.is_local_flags, caches=cache,
            cache_pos=pos_arr, enc_out=None)
        x = M.L.rms_norm(params["final_norm"], x, cfg.norm_eps)
        head = params.get("lm_head", params["embed"]["embedding"])
        logits = M.L.unembed({"embedding": head}, x[:, 0], cfg)
        logits = logits.at[..., cfg.vocab_size:].set(-1e9)
        if self.scfg.temperature > 0:
            nxt = jax.random.categorical(
                key, logits / self.scfg.temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32), new_cache

    def _prefill_jit(self, bucket):
        if bucket not in self._prefill:
            cfg = self.cfg

            def fn(params, toks, cache, slot):
                sub = jax.tree.map(
                    lambda c: jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=1),
                    cache)
                logits, new_sub = M.prefill(params, {"tokens": toks}, cfg, sub)
                cache = jax.tree.map(
                    lambda c, n: jax.lax.dynamic_update_slice_in_dim(
                        c, n.astype(c.dtype), slot, axis=1),
                    cache, new_sub)
                return logits, cache

            self._prefill[bucket] = jax.jit(fn)
        return self._prefill[bucket]

    # ------------------------------------------------------- public API
    def submit(self, req: Request):
        self.queue.append(req)
        self.out[req.uid] = []

    def _admit(self):
        for slot in range(self.scfg.slots):
            if self.active[slot] or not self.queue:
                continue
            req = self.queue.popleft()
            plen = len(req.prompt)
            bucket = min(self.scfg.max_seq,
                         max(self.scfg.prefill_bucket,
                             1 << (plen - 1).bit_length()))
            toks = np.zeros((1, bucket), np.int32)
            toks[0, :plen] = req.prompt
            logits, self.cache = self._prefill_jit(bucket)(
                self.params, jnp.asarray(toks), self.cache, slot)
            # note: bucket-padded prefill attends only up to plen thanks
            # to causal masking of positions >= plen at decode time? No:
            # padded tail occupies cache. We instead track pos=plen and
            # overwrite padded entries as decode advances.
            self.pos[slot] = plen
            self.active[slot] = True
            self.uid[slot] = req.uid
            self.budget[slot] = req.max_new_tokens or self.scfg.max_new_tokens
            # first generated token: greedy from prefill logits at plen-1.
            # prefill returns last-position logits of the padded bucket,
            # so recompute from prompt end via one decode of last token.
            self.last_tok[slot] = int(req.prompt[-1])
            self.pos[slot] = plen - 1

    def step(self, key=None):
        """One engine tick: admit + one decode step for all slots."""
        self._admit()
        if not self.active.any():
            return False
        key = key if key is not None else jax.random.PRNGKey(0)
        toks = jnp.asarray(self.last_tok)
        pos = jnp.asarray(self.pos)
        nxt, self.cache = self._decode(self.params, toks, pos, self.cache,
                                       key)
        nxt = np.asarray(nxt)
        for slot in range(self.scfg.slots):
            if not self.active[slot]:
                continue
            tok = int(nxt[slot])
            self.out[self.uid[slot]].append(tok)
            self.pos[slot] += 1
            self.last_tok[slot] = tok
            self.budget[slot] -= 1
            if tok == self.scfg.eos_id or self.budget[slot] <= 0 \
                    or self.pos[slot] >= self.scfg.max_seq - 1:
                self.active[slot] = False
        return True

    def run_to_completion(self, max_ticks=10_000):
        ticks = 0
        while (self.queue or self.active.any()) and ticks < max_ticks:
            self.step(jax.random.PRNGKey(ticks))
            ticks += 1
        return {uid: toks for uid, toks in self.out.items()}
