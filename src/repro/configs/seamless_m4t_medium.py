"""seamless-m4t-medium [audio] — enc-dec, 12L each, d_model=1024 16H
(GQA kv=16) d_ff=4096 vocab=256206; modality frontend STUBBED as
precomputed frame embeddings [arXiv:2308.11596; hf]."""
import jax.numpy as jnp
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    num_layers=12, num_encoder_layers=12,
    d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=256206,
    prefix_embed_dim=1024,  # audio frame embedding width (stub)
    rope_theta=10000.0, tie_embeddings=True, dtype=jnp.bfloat16)

SMOKE = CONFIG.with_(
    num_layers=2, num_encoder_layers=2, d_model=96, n_heads=4,
    n_kv_heads=4, head_dim=24, d_ff=192, vocab_size=512,
    prefix_embed_dim=48, dtype=jnp.float32)
