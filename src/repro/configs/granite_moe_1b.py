"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8)
d_ff=512, MoE 32 experts top-8, vocab=49155
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
import jax.numpy as jnp
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="decoder",
    num_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155,
    moe=True, num_experts=32, top_k=8,
    rope_theta=10000.0, tie_embeddings=True, dtype=jnp.bfloat16)

SMOKE = CONFIG.with_(
    num_layers=4, d_model=96, n_heads=4, n_kv_heads=2, head_dim=24,
    d_ff=64, num_experts=8, top_k=2, vocab_size=512, dtype=jnp.float32)
