"""Architecture registry: ``--arch <id>`` -> ModelConfig.

Ten assigned architectures (see DESIGN.md), each with the exact
full-size CONFIG from the assignment and a reduced SMOKE config of the
same family for CPU tests.
"""
from __future__ import annotations

import importlib

_ARCHS = {
    "gemma2-2b": "gemma2_2b",
    "qwen2.5-14b": "qwen2_5_14b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "kimi-k2-1t-a32b": "kimi_k2",
    "pixtral-12b": "pixtral_12b",
    "hymba-1.5b": "hymba_1_5b",
    "mamba2-130m": "mamba2_130m",
}


def list_archs() -> list[str]:
    return list(_ARCHS)


def get_config(arch: str, smoke: bool = False):
    if arch not in _ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {list(_ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{_ARCHS[arch]}")
    return mod.SMOKE if smoke else mod.CONFIG
