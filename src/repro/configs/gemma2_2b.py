"""gemma2-2b [dense] — 26L d_model=2304 8H (GQA kv=4) d_ff=9216
vocab=256000; local+global alternating attention, logit softcaps
[arXiv:2408.00118; hf]."""
import jax.numpy as jnp
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b", family="decoder",
    num_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=9216, vocab_size=256000,
    layer_pattern="local_global", local_window=4096,
    attn_softcap=50.0, final_softcap=30.0, attn_scale=256 ** -0.5,
    post_norms=True, scale_embeddings=True, tie_embeddings=True,
    rope_theta=10000.0, dtype=jnp.bfloat16)

SMOKE = CONFIG.with_(
    num_layers=4, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab_size=512, local_window=16, dtype=jnp.float32)
