"""Input-shape sets for the assigned architectures.

Every LM arch is paired with four shapes (assignment):
  train_4k     seq_len=4096   global_batch=256  -> train_step
  prefill_32k  seq_len=32768  global_batch=32   -> prefill
  decode_32k   seq_len=32768  global_batch=128  -> decode_step (1 token,
                                                   KV/state at 32k)
  long_500k    seq_len=524288 global_batch=1    -> decode_step; only for
               sub-quadratic archs (ssm/hybrid) per the assignment —
               pure full-attention archs skip it (DESIGN.md
               §Arch-applicability).

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input — shardable, weak-type-correct, zero allocation — plus the
name of the step the dry-run lowers.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

#: number of stubbed modality-prefix positions for VLM archs.
VLM_PATCHES = 1024


def applicable(cfg, shape_name: str) -> tuple[bool, str]:
    """Whether (arch, shape) is an assigned cell; reason if not."""
    if shape_name == "long_500k" and cfg.family not in ("mamba", "hybrid"):
        return False, ("long_500k requires sub-quadratic sequence mixing; "
                       f"{cfg.name} is pure full-attention (assignment: skip)")
    return True, ""


def token_count(cfg, shape_name: str) -> int:
    """Processed tokens per step (for MODEL_FLOPS accounting)."""
    s = SHAPES[shape_name]
    if s.kind == "decode":
        return s.global_batch  # one new token per sequence
    n = s.seq_len * s.global_batch
    if cfg.family == "encdec":
        n *= 2  # encoder frames + decoder tokens
    return n


def input_specs(cfg, shape_name: str) -> tuple[dict, str]:
    """(kwargs of ShapeDtypeStructs for the step, step kind)."""
    s = SHAPES[shape_name]
    b, l = s.global_batch, s.seq_len
    i32 = jnp.int32
    f32 = jnp.float32

    def arr(shape, dt=i32):
        return jax.ShapeDtypeStruct(shape, dt)

    if s.kind == "train":
        if cfg.family == "encdec":
            batch = {"enc_embeds": arr((b, l, cfg.prefix_embed_dim), f32),
                     "tokens": arr((b, l)), "labels": arr((b, l))}
        elif cfg.prefix_embed_dim:  # vlm: patches + text fill seq_len
            npatch = min(VLM_PATCHES, l // 4)
            batch = {"prefix_embeds": arr((b, npatch, cfg.prefix_embed_dim), f32),
                     "tokens": arr((b, l - npatch)),
                     "labels": arr((b, l))}
        else:
            batch = {"tokens": arr((b, l)), "labels": arr((b, l))}
        return {"batch": batch}, "train"

    if s.kind == "prefill":
        if cfg.family == "encdec":
            batch = {"enc_embeds": arr((b, l, cfg.prefix_embed_dim), f32),
                     "tokens": arr((b, l))}
        elif cfg.prefix_embed_dim:
            npatch = min(VLM_PATCHES, l // 4)
            batch = {"prefix_embeds": arr((b, npatch, cfg.prefix_embed_dim), f32),
                     "tokens": arr((b, l - npatch))}
        else:
            batch = {"tokens": arr((b, l))}
        return {"batch": batch, "max_seq": l}, "prefill"

    # decode: one new token against a seq_len-deep cache
    out = {"tokens": arr((b, 1)), "max_seq": l}
    if cfg.family == "encdec":
        out["enc_out"] = arr((b, min(l, 32768), cfg.d_model), cfg.dtype)
    return out, "decode"
