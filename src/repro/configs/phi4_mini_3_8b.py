"""phi4-mini-3.8b [dense] — 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064; RoPE SwiGLU GQA [arXiv:2412.08905; hf]."""
import jax.numpy as jnp
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b", family="decoder",
    num_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=200064,
    rope_theta=10000.0, tie_embeddings=True, dtype=jnp.bfloat16)

SMOKE = CONFIG.with_(
    num_layers=4, d_model=96, n_heads=6, n_kv_heads=2, head_dim=16,
    d_ff=192, vocab_size=512, dtype=jnp.float32)
