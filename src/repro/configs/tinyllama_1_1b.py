"""tinyllama-1.1b [dense] — 22L d_model=2048 32H (GQA kv=4) d_ff=5632
vocab=32000; llama2-arch small [arXiv:2401.02385; hf]."""
import jax.numpy as jnp
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b", family="decoder",
    num_layers=22, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=64,
    d_ff=5632, vocab_size=32000,
    rope_theta=10000.0, tie_embeddings=False, dtype=jnp.bfloat16)

SMOKE = CONFIG.with_(
    num_layers=4, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=256, vocab_size=512, dtype=jnp.float32)
