"""mamba2-130m [ssm] — 24L d_model=768, attention-free SSD
(state-space duality), ssm_state=128, vocab=50280
[arXiv:2405.21060; unverified]."""
import jax.numpy as jnp
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="mamba",
    num_layers=24, d_model=768, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_conv=4, ssm_expand=2, ssm_head_dim=64,
    ssm_groups=1, ssm_chunk=256,
    tie_embeddings=True, dtype=jnp.bfloat16)

SMOKE = CONFIG.with_(
    num_layers=4, d_model=64, ssm_state=16, ssm_head_dim=32,
    vocab_size=512, ssm_chunk=16, dtype=jnp.float32)
