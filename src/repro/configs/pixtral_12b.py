"""pixtral-12b [vlm] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072; pixtral-ViT frontend STUBBED as precomputed patch
embeddings + mistral-nemo-style decoder
[hf:mistralai/Pixtral-12B-2409; unverified]."""
import jax.numpy as jnp
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="decoder",
    num_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131072,
    prefix_embed_dim=1024,  # vision encoder width (stub)
    rope_theta=1000000.0, tie_embeddings=False, dtype=jnp.bfloat16)

SMOKE = CONFIG.with_(
    num_layers=4, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=256, vocab_size=512, prefix_embed_dim=48, dtype=jnp.float32)
