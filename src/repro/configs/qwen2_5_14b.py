"""qwen2.5-14b [dense] — 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064; GQA with QKV bias [hf:Qwen/Qwen2.5-14B; hf]."""
import jax.numpy as jnp
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="decoder",
    num_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=13824, vocab_size=152064, qkv_bias=True,
    rope_theta=1000000.0, tie_embeddings=False, dtype=jnp.bfloat16)

SMOKE = CONFIG.with_(
    num_layers=4, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=256, vocab_size=512, dtype=jnp.float32)
