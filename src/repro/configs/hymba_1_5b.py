"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504,
parallel attention+mamba heads per layer, ssm_state=16, vocab=32001;
sliding-window attention except 3 global layers [arXiv:2411.13676; hf]."""
import jax.numpy as jnp
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab_size=32001,
    layer_pattern="sparse_global", local_window=1024,
    ssm_state=16, ssm_conv=4, ssm_expand=2, ssm_head_dim=64, ssm_groups=1,
    rope_theta=10000.0, tie_embeddings=True, dtype=jnp.bfloat16)

SMOKE = CONFIG.with_(
    num_layers=4, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=256, local_window=16, ssm_state=8, ssm_head_dim=32,
    vocab_size=512, dtype=jnp.float32)
