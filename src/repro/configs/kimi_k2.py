"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) d_ff=2048,
MoE 384 experts top-8 (+1 shared), vocab=163840 — trillion-param MoE
(paper-table) [arXiv:2501.kimi2; unverified]."""
import jax.numpy as jnp
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="decoder",
    num_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=112,
    d_ff=2048, vocab_size=163840,
    moe=True, num_experts=384, top_k=8, num_shared_experts=1,
    rope_theta=50000.0, tie_embeddings=False, dtype=jnp.bfloat16)

SMOKE = CONFIG.with_(
    num_layers=3, d_model=96, n_heads=4, n_kv_heads=2, head_dim=24,
    d_ff=64, num_experts=8, top_k=2, num_shared_experts=1,
    vocab_size=512, dtype=jnp.float32)
