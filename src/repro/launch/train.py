"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --smoke --steps 100 --batch 8 --seq 128

Runs the full production loop on whatever devices exist: data pipeline
(list-ranking packed), pjit'd train step with the resolved shardings,
fault-tolerant supervisor (periodic async checkpoints, crash restart,
preemption handling), metrics logging.
"""
from __future__ import annotations

import argparse
import functools
import json
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.data import pipeline
from repro.launch import mesh as mesh_lib
from repro.models import model as M
from repro.models.params import abstract_params
from repro.optim import adamw
from repro.runtime import context as runtime_context
from repro.runtime import sharding as shlib
from repro.runtime.fault_tolerance import Supervisor, SupervisorConfig
from repro.train import steps as train_steps


def build(arch: str, smoke: bool, batch: int, seq: int, mesh,
          tcfg: train_steps.TrainConfig, use_kernels: bool = False):
    cfg = configs.get_config(arch, smoke=smoke)
    cfg = cfg.with_(use_kernels=use_kernels)
    specs_tree = M.param_specs(cfg)
    report = shlib.ResolveReport()
    params_sh = shlib.tree_shardings(specs_tree, mesh, report=report)
    opt_sh = adamw.state_shardings(specs_tree, mesh, tcfg.optimizer)
    dcfg = pipeline.DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                               global_batch=batch)
    from jax.sharding import NamedSharding, PartitionSpec as P
    batch_sh = {
        "tokens": NamedSharding(mesh, shlib.resolve_spec(
            (batch, seq), ("batch", "seq"), mesh)),
        "labels": NamedSharding(mesh, shlib.resolve_spec(
            (batch, seq), ("batch", "seq"), mesh)),
    }
    base_step = functools.partial(train_steps.train_step, cfg=cfg,
                                  tcfg=tcfg)

    def step_fn_wrapped(params, opt, batch):
        with runtime_context.use_mesh(mesh):
            return base_step(params, opt, batch)

    step_fn = jax.jit(
        step_fn_wrapped,
        in_shardings=(params_sh, opt_sh, batch_sh),
        out_shardings=(params_sh, opt_sh, None),
        donate_argnums=(0, 1))
    return cfg, dcfg, params_sh, opt_sh, batch_sh, step_fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=configs.list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--use-kernels", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    mesh = mesh_lib.make_host_mesh()
    tcfg = train_steps.TrainConfig(
        optimizer=adamw.AdamWConfig(lr=args.lr),
        warmup_steps=max(args.steps // 10, 1), total_steps=args.steps)
    cfg, dcfg, params_sh, opt_sh, batch_sh, step_fn = build(
        args.arch, args.smoke, args.batch, args.seq, mesh, tcfg,
        args.use_kernels)

    def init_state():
        params = jax.jit(functools.partial(M.init, cfg=cfg),
                         out_shardings=params_sh)(jax.random.PRNGKey(0))
        opt = jax.jit(functools.partial(adamw.init, cfg=tcfg.optimizer),
                      out_shardings=opt_sh)(params)
        return (params, opt), 0

    def restore_like():
        params_abs = abstract_params(M.param_specs(cfg))
        opt_abs = jax.eval_shape(
            functools.partial(adamw.init, cfg=tcfg.optimizer), params_abs)
        return (params_abs, opt_abs)

    sup = Supervisor(SupervisorConfig(ckpt_dir=args.ckpt_dir,
                                      ckpt_every=args.ckpt_every),
                     init_state, restore_like,
                     shardings=(params_sh, opt_sh))
    sup.install_signal_handlers()

    losses = []

    def one_step(state, step):
        params, opt = state
        batch = pipeline.device_batch(dcfg, step, mesh, batch_sh)
        params, opt, metrics = step_fn(params, opt, batch)
        return (params, opt), metrics

    def on_metrics(step, metrics):
        if step % args.log_every == 0 or step == args.steps:
            loss = float(metrics["loss"])
            losses.append((step, loss))
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e}", flush=True)

    t0 = time.time()
    state, step = sup.run(one_step, args.steps, on_metrics)
    dt = time.time() - t0
    print(json.dumps({"arch": cfg.name, "steps": step,
                      "wall_s": round(dt, 1),
                      "supervisor": sup.stats,
                      "first_loss": losses[0][1] if losses else None,
                      "last_loss": losses[-1][1] if losses else None}))
    return losses


if __name__ == "__main__":
    main()
