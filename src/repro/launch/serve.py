"""Serving driver: batched generation with continuous batching.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --smoke --requests 12 --max-new 32
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro import configs
from repro.models import model as M
from repro.serve.engine import Request, ServeConfig, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=configs.list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    if cfg.family == "encdec":
        raise SystemExit("encdec serving demo: use examples/translate.py")
    params = M.init(jax.random.PRNGKey(0), cfg)
    scfg = ServeConfig(slots=args.slots, max_seq=args.max_seq,
                       temperature=args.temperature,
                       max_new_tokens=args.max_new)
    eng = ServingEngine(params, cfg, scfg)
    rng = np.random.default_rng(0)
    for uid in range(args.requests):
        plen = int(rng.integers(4, 32))
        eng.submit(Request(uid=uid, prompt=rng.integers(
            2, cfg.vocab_size, plen).astype(np.int32)))
    t0 = time.time()
    out = eng.run_to_completion()
    dt = time.time() - t0
    total = sum(len(v) for v in out.values())
    print(json.dumps({
        "arch": cfg.name, "requests": len(out),
        "generated_tokens": total, "wall_s": round(dt, 2),
        "tok_per_s": round(total / max(dt, 1e-9), 1),
        "sample": {str(k): v[:8] for k, v in list(out.items())[:2]},
    }))
    return out


if __name__ == "__main__":
    main()
