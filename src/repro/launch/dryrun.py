import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh, with zero array allocation (ShapeDtypeStruct inputs).

Per cell this produces:
  - the PRODUCTION compile (scanned layers + remat): its
    memory_analysis() (per-device bytes — does it fit HBM) and
    cost_analysis() are printed, per the dry-run contract;
  - exact per-device FLOP / byte / collective counts via *layer
    probes*: XLA's cost_analysis counts while-loop (scan) bodies once,
    and fully unrolling 26-61 layer models at 512 SPMD partitions costs
    10-20 min per cell on this CPU container. Instead two small
    UNROLLED probes (2 and 4 layers, same d_model/shape/sharding) are
    compiled and the per-layer slope extrapolates to the full depth —
    exact for depth-homogeneous stacks, pattern-aware for alternating
    (gemma2) and sparse-global (hymba) stacks. Validated against a
    fully-unrolled tinyllama train_4k compile: collective bytes exact
    (0.0%), FLOPs within 5.6%, HLO-bytes within 28% (the XLA:CPU bytes
    counter varies with fusion depth; treated as an upper bound —
    EXPERIMENTS.md §Roofline).
  - a collective inventory parsed from the probes' post-SPMD HLO
    (all-gather / all-reduce / reduce-scatter / all-to-all / permute),
  - the three roofline terms + dominant bottleneck + MODEL_FLOPS ratio.

Usage:
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k \
      [--multi-pod] [--out benchmarks/results/dryrun]
  python -m repro.launch.dryrun --all [--multi-pod] [--jobs 2]
"""
import argparse
import functools
import json
import pathlib
import re
import subprocess
import sys
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs import shapes as SH
from repro.launch import mesh as mesh_lib
from repro.models import model as M
from repro.models.params import abstract_params, count_params
from repro.optim import adamw
from repro.runtime import context as runtime_context
from repro.runtime import sharding as shlib
from repro.train import steps as train_steps

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(txt: str) -> int:
    m = _SHAPE_RE.match(txt)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum output-shape bytes of every collective op in the (post-SPMD,
    per-device) optimized HLO."""
    out = {k: 0.0 for k in COLLECTIVES}
    out["collective_ops"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?[\w.\-]+ = ((?:\([^)]*\)|\S+)) ([\w\-]+?)(-start)?\(",
                     s)
        if not m:
            continue
        shape_txt, op, _ = m.groups()
        if op not in COLLECTIVES:
            continue
        total = sum(_shape_bytes(t) for t in
                    re.findall(r"\w+\[[\d,]*\]", shape_txt))
        out[op] += total
        out["collective_ops"] += 1
    return out


BATCH_AXES = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "prefix_embeds": ("batch", "seq", None),
    "enc_embeds": ("batch", "seq", None),
}


def _batch_shardings(batch_specs, mesh, report):
    out = {}
    for k, v in batch_specs.items():
        axes = BATCH_AXES.get(k, ("batch",) + (None,) * (len(v.shape) - 1))
        out[k] = jax.NamedSharding(
            mesh, shlib.resolve_spec(v.shape, axes, mesh, name=f"batch/{k}",
                                     report=report))
    return out


def _tree_shardings_from_axes(tree_abstract, axes_tree, mesh, report, prefix):
    def one(path, leaf, axes):
        name = prefix + "/" + "/".join(str(getattr(p, "key", p)) for p in path)
        return jax.NamedSharding(
            mesh, shlib.resolve_spec(leaf.shape, axes, mesh, name=name,
                                     report=report))
    paths = jax.tree_util.tree_flatten_with_path(tree_abstract)[0]
    # an axes leaf is a tuple of axis names/None; containers are
    # NamedTuples or plain tuples of sub-trees
    def _axes_leaf(x):
        return (type(x) is tuple and
                all(e is None or isinstance(e, str) for e in x))
    flat_axes = jax.tree.leaves(axes_tree, is_leaf=_axes_leaf)
    return jax.tree.unflatten(
        jax.tree.structure(tree_abstract),
        [one(p, l, a) for (p, l), a in zip(paths, flat_axes)])


def _lower(cfg, shape_name, mesh, rules, report, zero1, donate):
    """Lower the cell's step for ``cfg``. Returns jax.stages.Lowered."""
    kwargs, kind = SH.input_specs(cfg, shape_name)
    specs_tree = M.param_specs(cfg)
    params_abs = abstract_params(specs_tree)
    params_sh = shlib.tree_shardings(specs_tree, mesh, rules, report)

    with runtime_context.use_mesh(mesh):
        if kind == "train":
            tcfg = train_steps.TrainConfig()
            opt_abs = jax.eval_shape(
                functools.partial(adamw.init, cfg=tcfg.optimizer), params_abs)
            opt_sh = adamw.state_shardings(specs_tree, mesh, tcfg.optimizer,
                                           rules, zero1=zero1)
            batch_sh = _batch_shardings(kwargs["batch"], mesh, report)
            fn = functools.partial(train_steps.train_step, cfg=cfg, tcfg=tcfg)
            jitted = jax.jit(
                fn, in_shardings=(params_sh, opt_sh, batch_sh),
                out_shardings=(params_sh, opt_sh, None),
                donate_argnums=(0, 1) if donate else ())
            return jitted.lower(params_abs, opt_abs, kwargs["batch"]), kind
        if kind == "prefill":
            b = SH.SHAPES[shape_name].global_batch
            cache_abs = jax.eval_shape(
                functools.partial(M.init_cache, cfg, b, kwargs["max_seq"]))
            cache_sh = _tree_shardings_from_axes(
                cache_abs, M.cache_axes(cfg), mesh, report, "cache")
            batch_sh = _batch_shardings(kwargs["batch"], mesh, report)
            fn = functools.partial(M.prefill, cfg=cfg)
            jitted = jax.jit(lambda p, b_, c: fn(p, b_, cache=c),
                             in_shardings=(params_sh, batch_sh, cache_sh),
                             out_shardings=(None, cache_sh),
                             donate_argnums=(2,) if donate else ())
            return jitted.lower(params_abs, kwargs["batch"], cache_abs), kind
        # decode
        b = SH.SHAPES[shape_name].global_batch
        cache_abs = jax.eval_shape(
            functools.partial(M.init_cache, cfg, b, kwargs["max_seq"]))
        cache_sh = _tree_shardings_from_axes(cache_abs, M.cache_axes(cfg),
                                             mesh, report, "cache")
        tok = kwargs["tokens"]
        tok_sh = jax.NamedSharding(
            mesh, shlib.resolve_spec(tok.shape, ("batch", None), mesh,
                                     name="tokens", report=report))
        args = [params_abs, tok, cache_abs]
        in_sh = [params_sh, tok_sh, cache_sh]
        if "enc_out" in kwargs:
            enc_sh = jax.NamedSharding(
                mesh, shlib.resolve_spec(kwargs["enc_out"].shape,
                                         ("batch", "seq", None), mesh,
                                         name="enc_out", report=report))
            fn = lambda p, t, c, e: M.decode_step(
                p, t, kwargs["max_seq"] - 1, cfg, c, e)
            args.append(kwargs["enc_out"])
            in_sh.append(enc_sh)
        else:
            fn = lambda p, t, c: M.decode_step(
                p, t, kwargs["max_seq"] - 1, cfg, c)
        jitted = jax.jit(fn, in_shardings=tuple(in_sh),
                         out_shardings=(None, cache_sh),
                         donate_argnums=(2,) if donate else ())
        return jitted.lower(*args), kind


def _probe_costs(cfg, shape_name, mesh, rules, zero1, donate):
    """Compile a small UNROLLED model and return its cost dict."""
    report = shlib.ResolveReport()
    lowered, _ = _lower(cfg.with_(scan_layers=False), shape_name, mesh,
                        rules, report, zero1, donate)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    out = {"flops": float(cost.get("flops", 0.0)),
           "bytes": float(cost.get("bytes accessed", 0.0))}
    out.update({f"coll/{k}": float(v) for k, v in coll.items()})
    del compiled
    return out


def _probe_pair(cfg, shape_name, mesh, rules, zero1, donate, l1, l2,
                **cfg_kw):
    """Linear (intercept, slope) of every cost key between l1 and l2."""
    c1 = _probe_costs(cfg.with_(num_layers=l1, **cfg_kw), shape_name, mesh,
                      rules, zero1, donate)
    c2 = _probe_costs(cfg.with_(num_layers=l2, **cfg_kw), shape_name, mesh,
                      rules, zero1, donate)
    out = {}
    for k in c1:
        slope = (c2[k] - c1[k]) / (l2 - l1)
        out[k] = (c1[k] - slope * l1, slope)  # (intercept, per-layer)
    return out


def estimate_costs(cfg, shape_name, mesh, rules, zero1, donate):
    """Extrapolated exact cost counts for the full-depth model."""
    L = cfg.num_layers
    if cfg.family == "encdec":
        # probe (enc, dec) layer pairs jointly — seamless has equal
        # encoder/decoder depth, so depth scales both stacks together
        c22 = _probe_costs(cfg.with_(num_layers=2, num_encoder_layers=2),
                           shape_name, mesh, rules, zero1, donate)
        c44 = _probe_costs(cfg.with_(num_layers=4, num_encoder_layers=4),
                           shape_name, mesh, rules, zero1, donate)
        est = {}
        for k in c22:
            slope = (c44[k] - c22[k]) / 2.0
            est[k] = c22[k] + slope * (L - 2)
        return est, {"probe_l": [2, 4], "mode": "encdec-pairs"}
    if cfg.layer_pattern == "sparse_global":
        n_glob = 3
        loc = _probe_pair(cfg, shape_name, mesh, rules, zero1, donate,
                          2, 4, layer_pattern="local_only")
        glo = _probe_pair(cfg, shape_name, mesh, rules, zero1, donate,
                          2, 4, layer_pattern="global")
        est = {}
        for k in loc:
            b_l, s_l = loc[k]
            _, s_g = glo[k]
            est[k] = b_l + s_l * (L - n_glob) + s_g * n_glob
        return est, {"probe_l": [2, 4], "mode": "sparse-global-corrected"}
    # homogeneous or period-2 alternating stacks. Probing at 4 and 8
    # keeps the small-depth fusion edge effects out of the slope.
    l1, l2 = (4, 8) if L >= 8 else (2, 4)
    fits = _probe_pair(cfg, shape_name, mesh, rules, zero1, donate, l1, l2)
    est = {k: b + s * L for k, (b, s) in fits.items()}
    return est, {"probe_l": [l1, l2], "mode": "linear"}




def analytic_memory_bytes(cfg, shape_name, kind, chips, n_params, active):
    """TPU-fused per-device HBM traffic estimate (documented model).

    XLA:CPU's 'bytes accessed' counts every unfused op, overstating a
    real TPU executable's HBM traffic by 10-50x (elementwise chains
    fuse). This model is the fused *lower* bound the §Roofline table
    reports next to the HLO upper bound:

      train : params  active*2B*3 (fwd+bwd+remat reads)
              + n_params*(4B*6) (adam m/v/master fp32 read+write)
              + acts tokens/dev * d_model * layers * 2B * 20
              + logits tokens/dev * padded_vocab * 4B * 2
      serve : params active*2B + cache read+write + acts (k=8)
    """
    tokens = SH.token_count(cfg, shape_name)
    tok_dev = tokens / chips
    L = cfg.num_layers + cfg.num_encoder_layers
    d = cfg.d_model
    if kind == "train":
        par = active / chips * 2 * 3 + n_params / chips * 4 * 6
        acts = tok_dev * d * L * 2 * 20
        logits = tok_dev * cfg.padded_vocab * 4 * 2
        return par + acts + logits
    # serving
    par = active / chips * 2
    acts = tok_dev * d * L * 2 * 8
    s = SH.SHAPES[shape_name]
    if cfg.family in ("decoder", "encdec", "hybrid"):
        kv = (L * s.global_batch * cfg.n_kv_heads * s.seq_len
              * cfg.resolved_head_dim * 2 * 2) / chips
    else:
        kv = 0.0
    if cfg.family in ("mamba", "hybrid"):
        d_inner = cfg.ssm_expand * d
        h = d_inner // cfg.ssm_head_dim
        kv += (cfg.num_layers * s.global_batch * h * cfg.ssm_state
               * cfg.ssm_head_dim * 4 * 2) / chips
    mult = 2 if kind == "prefill" else 1  # prefill writes what it reads
    logits = (s.global_batch if kind != "train" else tok_dev)         * cfg.padded_vocab * 4 / chips
    return par + acts + kv * mult + logits

def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               zero1: bool = True, donate: bool = True,
               remat: bool = True, extra_rules: dict | None = None,
               cfg_override=None, probes: bool = True,
               remat_policy: str = "nothing"):
    """Compile the production (scanned) executable + probe costs."""
    cfg = cfg_override or configs.get_config(arch)
    cfg = cfg.with_(remat=remat, use_kernels=False, scan_layers=True,
                    remat_policy=remat_policy)
    ok, why = SH.applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}, None
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    rules = dict(shlib.DEFAULT_RULES)
    if extra_rules:
        rules.update(extra_rules)
    report = shlib.ResolveReport()

    t0 = time.time()
    lowered, kind = _lower(cfg, shape_name, mesh, rules, report, zero1,
                           donate)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost_scan = compiled.cost_analysis()

    t0 = time.time()
    if probes:
        est, probe_info = estimate_costs(cfg, shape_name, mesh, rules,
                                         zero1, donate)
    else:
        est = {"flops": float(cost_scan.get("flops", 0.0)),
               "bytes": float(cost_scan.get("bytes accessed", 0.0))}
        est.update({f"coll/{k}": 0.0 for k in COLLECTIVES})
        probe_info = {"mode": "scan-body-once (no probes)"}
    t_probe = time.time() - t0

    chips = 512 if multi_pod else 256
    flops_dev = est["flops"]
    bytes_dev = est["bytes"]
    coll = {k: est.get(f"coll/{k}", 0.0) for k in COLLECTIVES}
    coll["collective_ops"] = est.get("coll/collective_ops", 0.0)
    coll_dev = float(sum(coll[k] for k in COLLECTIVES))

    specs_tree = M.param_specs(cfg)
    n_params = count_params(specs_tree)
    if cfg.moe:
        active = count_params(M.param_specs(
            cfg.with_(num_experts=max(cfg.top_k, 1))))
    else:
        active = n_params

    bytes_model = analytic_memory_bytes(cfg, shape_name, kind, chips,
                                        n_params, active)
    t_comp = flops_dev / mesh_lib.PEAK_FLOPS_BF16
    t_mem_hlo = bytes_dev / mesh_lib.HBM_BW
    t_mem = bytes_model / mesh_lib.HBM_BW
    t_coll = coll_dev / mesh_lib.ICI_BW
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    bottleneck = max(terms, key=terms.get)
    tokens = SH.token_count(cfg, shape_name)
    mult = 6 if kind == "train" else 2
    model_flops = mult * active * tokens
    hlo_flops_global = flops_dev * chips
    useful = model_flops / hlo_flops_global if hlo_flops_global else 0.0

    terms_out = dict(terms)
    terms_out["memory_hlo_s"] = t_mem_hlo
    record = {
        "arch": arch, "shape": shape_name, "kind": kind,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "probe_s": round(t_probe, 1), "probe_info": probe_info,
        "params": n_params, "active_params": active,
        "memory": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "alias_bytes_per_device": mem.alias_size_in_bytes,
            "peak_bytes_per_device": mem.argument_size_in_bytes
            + mem.output_size_in_bytes + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "cost": {"flops_per_device": flops_dev,
                 "bytes_per_device_hlo": bytes_dev,
                 "bytes_per_device_model": bytes_model,
                 "scan_flops_per_device": float(cost_scan.get("flops", 0.0))},
        "collectives": coll,
        "roofline": {**terms_out, "bottleneck": bottleneck,
                     "model_flops": model_flops,
                     "useful_flops_ratio": useful,
                     "step_time_bound_s": max(terms.values()),
                     "mfu_bound": model_flops / chips
                     / mesh_lib.PEAK_FLOPS_BF16
                     / max(max(terms.values()), 1e-12)},
        "sharding_downgrades": report.downgrades,
    }
    return record, compiled


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

RULE_PRESETS = {
    # DP over the data axis only (16 seqs/device); model axis left for
    # ZeRO state sharding. Params replicated.
    "dp16": {
        "batch": (("data",), ()),
        "mlp": ((),), "qkv_features": ((),), "kv_features": ((),),
        "heads": ((),), "kv_heads": ((),), "head_dim": ((),),
        "vocab": (("model",), ()),
    },
    # pure data-parallel: batch over every mesh axis, params replicated,
    # optimizer states ZeRO-sharded. The right mapping for <=3B dense
    # models at train_4k (see EXPERIMENTS.md §Perf P-dense).
    "dp": {
        "batch": (("pod", "data", "model"), ("data", "model"), ("data",), ()),
        "mlp": ((),), "qkv_features": ((),), "kv_features": ((),),
        "heads": ((),), "kv_heads": ((),), "head_dim": ((),),
        "vocab": (("model",), ()),
    },
}


def run_one(args):
    rec, compiled = lower_cell(args.arch, args.shape, args.multi_pod,
                               zero1=not args.no_zero1,
                               remat=not args.no_remat,
                               probes=not args.no_probes,
                               extra_rules=RULE_PRESETS.get(args.rules),
                               remat_policy=args.remat_policy)
    if compiled is not None:
        print(compiled.memory_analysis())   # proves it fits
        ca = compiled.cost_analysis()
        print({k: ca[k] for k in ("flops", "bytes accessed")
               if k in ca})                 # FLOPs/bytes for the roofline
    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    tag = "multi" if args.multi_pod else "single"
    if args.rules:
        tag += f"__{args.rules}"
    if args.remat_policy != "nothing":
        tag += f"__{args.remat_policy}"
        rec["rules"] = args.rules
    path = out_dir / f"{args.arch}__{args.shape}__{tag}.json"
    path.write_text(json.dumps(rec, indent=1))
    print(json.dumps({k: rec[k] for k in rec
                      if k not in ("memory", "cost", "collectives")}
                     if "skipped" not in rec else rec, indent=1))
    print("wrote", path)


def run_all(args):
    archs = args.archs.split(",") if args.archs else configs.list_archs()
    cells = [(a, s) for a in archs for s in SH.SHAPES]
    procs, failures = [], []

    def drain(block=False):
        while procs and (block or len(procs) >= args.jobs):
            for i, (p, a, s) in enumerate(procs):
                if p.poll() is not None:
                    if p.returncode != 0:
                        failures.append((a, s, p.returncode))
                        print(f"FAILED {a} {s} rc={p.returncode}", flush=True)
                    procs.pop(i)
                    break
            else:
                time.sleep(2.0)

    for arch, shape in cells:
        tag = "multi" if args.multi_pod else "single"
        path = pathlib.Path(args.out) / f"{arch}__{shape}__{tag}.json"
        if path.exists() and not args.force:
            print("cached", path.name, flush=True)
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--out", args.out]
        if args.multi_pod:
            cmd.append("--multi-pod")
        drain()
        print("launch", arch, shape, flush=True)
        procs.append((subprocess.Popen(cmd), arch, shape))
    drain(block=True)
    print("failures:", failures or "none")
    return 1 if failures else 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.list_archs())
    ap.add_argument("--shape", choices=list(SH.SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--archs", default="",
                    help="comma-separated arch filter for --all")
    ap.add_argument("--jobs", type=int, default=2)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--remat-policy", default="nothing",
                    choices=["nothing", "save_moe", "offload_moe"])
    ap.add_argument("--rules", default="",
                    help="named sharding-rule override (e.g. 'dp' = pure "
                         "data-parallel over data x model + ZeRO)")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    args = ap.parse_args()
    if args.all:
        sys.exit(run_all(args))
    if not args.arch or not args.shape:
        ap.error("--arch/--shape required (or --all)")
    run_one(args)


if __name__ == "__main__":
    main()
