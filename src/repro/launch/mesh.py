"""Production meshes.

Target: TPU v5e pods — 256 chips per pod arranged (16, 16); multi-pod
runs add a leading "pod" axis over the DCI. Functions (never module-
level constants) so importing this module never touches jax device
state — the dry-run sets XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax

from repro import compat

#: v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (~ per-chip eff. for ring)
DCI_BW = 25e9                 # inter-pod bytes/s per chip (conservative)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(shape=None, axes=("data", "model")):
    """Mesh over whatever devices exist (tests / smoke runs)."""
    n = len(jax.devices())
    if shape is None:
        shape = (n, 1)
    return compat.make_mesh(shape, axes)


def make_listrank_mesh(*, multi_pod: bool = False):
    """The same production mesh viewed as a flat PE grid for the list-
    ranking core: every chip is one PE; the axis factorization is what
    grid / topology-aware indirection route over (DESIGN.md §5)."""
    return make_production_mesh(multi_pod=multi_pod)
