"""Device-side telemetry plane: occupancy/skew counters + headroom.

PR 7's flight recorder times a stage from the host but cannot see
*inside* the traced program. This module is the other half: when
``ListRankConfig.telemetry=True`` (a **static** flag — part of every
jitted-program cache key via cfg/plan, so the telemetry-off program is
byte-identical to the committed goldens), every routing site emits a
small typed telemetry pytree as extra per-PE program outputs:

- per-hop mailbox **fill fractions** — ``fill_max`` is the hottest
  bucket's *demand* over the compiled cap (can exceed 1.0: that is
  exactly an overflow explained before it becomes a fatal counter),
  ``fill_mean_sum / rounds`` the mean delivered fill;
- per-hop **destination skew** — the hottest bucket's fraction of the
  wave's traffic (``dest_frac_max``), directly comparable to
  ``tuner.estimate_capacities``' sampled ``max_frac`` and its DKW
  margin;
- a coarse ``HIST_BINS``-bucket destination histogram over the hop-0
  coordinate, and queue-depth high-water marks.

Everything is carried **per PE** and aggregated host-side after the
existing output gather: no psums, no all_gathers — the telemetry-on
program has the *same* traced collective counts as telemetry-off
(pinned by ``introspect`` in tests). Per-PE carry beats in-program
psums because (a) the collective-count pins stay trivially true,
(b) cross-PE *spread* survives (a psum'd max loses which PE was hot),
and (c) the off-path stays source-identical.

The host half (:func:`aggregate`, :class:`StageRecord`,
:func:`headroom_rows`, :func:`format_headroom_table`,
:func:`dkw_backtest`) renders the capacity headroom report — observed
max fill / compiled cap, per family per level — cross-referenced
against the solver's escalation log so every capacity escalation is
explained in ``scales_log`` terms.

Only jax/numpy imports here: this module is imported by the exchange
layer and must not cycle back into ``repro.core.listrank``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Mapping, Sequence

import jax.numpy as jnp
import numpy as np

#: coarse destination-histogram resolution (hop-0 coordinate buckets).
HIST_BINS = 8

#: telemetry leaves merged by max (high-water marks / worst observed);
#: every other leaf is additive.
MAX_KEYS = frozenset({"fill_max", "dest_frac_max", "queue_hwm"})

#: the capacity families a stage can route under — the same names as
#: ``tuner.CapacityScales`` fields ("graph" covers the graphalg /
#: treealg front-door hooking/tour capacities).
STAGE_FAMILIES = ("chase", "sub", "gather", "graph")

TELEMETRY_HELP = {
    "fill_max": "hottest mailbox bucket demand / compiled cap (HWM; >1 explains an overflow)",
    "fill_mean": "mean delivered mailbox fill fraction per routing wave",
    "dest_frac_max": "hottest destination bucket's fraction of a wave's traffic (HWM)",
    "hist": "coarse destination histogram over the hop-0 coordinate",
    "rounds": "routing waves accumulated into this telemetry record",
    "queue_hwm": "outgoing-queue depth high-water mark (entries)",
    "util_max": "max mailbox fill fraction across hops/families of the stage",
    "util_mean": "mean delivered mailbox fill fraction of the stage",
}


# --------------------------------------------------------------------------
# device half: zeros + merge (used inside traced programs)
# --------------------------------------------------------------------------

def route_zero(depth: int):
    """Zero telemetry record of one routing family over a ``depth``-hop
    indirection. All leaves are fixed-shape so the record can ride a
    ``while_loop`` carry."""
    return {
        "fill_max": jnp.zeros((depth,), jnp.float32),
        "fill_mean_sum": jnp.zeros((depth,), jnp.float32),
        "dest_frac_max": jnp.zeros((depth,), jnp.float32),
        "hist": jnp.zeros((HIST_BINS,), jnp.int32),
        "rounds": jnp.int32(0),
    }


def stage_zero(depth: int):
    """Zero per-stage telemetry: one route record per capacity family
    plus the queue high-water mark. Uniform across stage kinds so every
    stage program has the same telemetry output shape."""
    tele = {fam: route_zero(depth) for fam in STAGE_FAMILIES}
    tele["queue_hwm"] = jnp.int32(0)
    return tele


def merge(a, b):
    """Merge two telemetry pytrees leafwise: :data:`MAX_KEYS` leaves
    take the elementwise max (high-water marks), everything else adds.
    ``None`` is the identity; keys are unioned (a partial increment
    merges into a full ``stage_zero`` record)."""
    if a is None:
        return b
    if b is None:
        return a
    out = {}
    for k in sorted(set(a) | set(b)):
        va, vb = a.get(k), b.get(k)
        if va is None:
            out[k] = vb
        elif vb is None:
            out[k] = va
        elif isinstance(va, dict):
            out[k] = merge(va, vb)
        elif k in MAX_KEYS:
            out[k] = jnp.maximum(va, vb)
        else:
            out[k] = va + vb
    return out


def route_wave(per_hop: Sequence[Mapping[str, jnp.ndarray]], hist):
    """Assemble one routing wave's telemetry from per-hop samples.

    ``per_hop[h]`` carries scalars ``demand_max`` (hottest bucket's
    message count), ``delivered`` (messages that fit), ``total`` (valid
    messages entering the hop), plus static ints ``cap`` and ``s``
    (peer-group size). ``hist`` is the hop-0 coarse histogram
    (``int32[HIST_BINS]``)."""
    f32 = jnp.float32
    fill_max = jnp.stack([
        h["demand_max"].astype(f32) / f32(max(int(h["cap"]), 1))
        for h in per_hop])
    fill_mean = jnp.stack([
        h["delivered"].astype(f32) / f32(max(int(h["cap"]) * int(h["s"]), 1))
        for h in per_hop])
    dest_frac = jnp.stack([
        h["demand_max"].astype(f32) / jnp.maximum(h["total"].astype(f32), 1.0)
        for h in per_hop])
    return {
        "fill_max": fill_max,
        "fill_mean_sum": fill_mean,
        "dest_frac_max": dest_frac,
        "hist": hist.astype(jnp.int32),
        "rounds": jnp.int32(1),
    }


def store_fill(depth: int, demand, cap: int):
    """A fill record for a non-routed capacity (sub/graph stores): the
    demand over the compiled cap, carried in slot 0 of a route-shaped
    record so it merges uniformly with routing telemetry."""
    rec = route_zero(depth)
    fill = demand.astype(jnp.float32) / jnp.float32(max(int(cap), 1))
    rec["fill_max"] = rec["fill_max"].at[0].set(fill)
    rec["fill_mean_sum"] = rec["fill_mean_sum"].at[0].set(
        jnp.minimum(fill, 1.0))
    rec["rounds"] = jnp.int32(1)
    return rec


# --------------------------------------------------------------------------
# host half: aggregation across the PE axis
# --------------------------------------------------------------------------

def aggregate(per_pe):
    """Reduce a gathered telemetry pytree — every leaf carries a
    leading ``(p,)`` PE axis — to plain-python host values. MAX leaves
    reduce by max over PEs, additive leaves by sum; per-PE spread is
    preserved for the fill HWM (``fill_max_by_pe`` max over hops) so
    cross-PE skew stays visible."""
    def red(tree, key=None):
        if isinstance(tree, Mapping):
            return {k: red(v, k) for k, v in tree.items()}
        arr = np.asarray(tree)
        if key in MAX_KEYS:
            return arr.max(axis=0)
        return arr.sum(axis=0)

    agg = red(per_pe)

    def attach_spread(node, src):
        for k, v in list(node.items()):
            if isinstance(v, dict):
                attach_spread(v, src[k])
            elif k == "fill_max":
                by_pe = np.asarray(src[k]).max(axis=-1)  # (p,)
                node["fill_max_pe_mean"] = float(by_pe.mean())

    attach_spread(agg, per_pe)
    return json_tele(agg)


def json_tele(tree):
    """Recursively convert telemetry leaves to JSON-safe python."""
    if isinstance(tree, Mapping):
        return {k: json_tele(v) for k, v in tree.items()}
    arr = np.asarray(tree)
    if arr.ndim == 0:
        return float(arr) if np.issubdtype(arr.dtype, np.floating) else int(arr)
    return [json_tele(v) for v in arr.tolist()] if arr.dtype.kind == "O" \
        else [float(v) if np.issubdtype(arr.dtype, np.floating) else int(v)
              for v in arr.tolist()]


def utilization(agg: Mapping) -> dict:
    """Stage-level utilization summary from an aggregated record:
    ``util_max`` (worst mailbox fill HWM over hops and families) and
    ``util_mean`` (mean delivered fill over waves that actually ran).
    Always finite; a stage that routed nothing reports zeros."""
    util_max = 0.0
    mean_num = mean_den = 0.0
    for fam in STAGE_FAMILIES:
        rec = agg.get(fam)
        if not rec:
            continue
        rounds = float(rec.get("rounds", 0))
        if rec.get("fill_max"):
            util_max = max(util_max, max(rec["fill_max"]))
        if rounds > 0 and rec.get("fill_mean_sum"):
            mean_num += sum(rec["fill_mean_sum"])
            mean_den += rounds * len(rec["fill_mean_sum"])
    util_mean = (mean_num / mean_den) if mean_den else 0.0
    return {"util_max": float(util_max), "util_mean": float(util_mean)}


@dataclasses.dataclass(frozen=True)
class StageRecord:
    """One committed stage attempt's aggregated telemetry + the caps
    it was compiled with: ``caps[family] = (cap per hop/leg,)``."""
    label: str
    kind: str
    level: int
    caps: dict
    queue_cap: int
    tele: dict

    def to_json(self) -> dict:
        return {"label": self.label, "kind": self.kind, "level": self.level,
                "caps": {k: list(v) for k, v in self.caps.items()},
                "queue_cap": int(self.queue_cap), "tele": self.tele,
                **utilization(self.tele)}

    @classmethod
    def from_json(cls, d: Mapping) -> "StageRecord":
        return cls(label=d["label"], kind=d["kind"], level=int(d["level"]),
                   caps={k: tuple(v) for k, v in d["caps"].items()},
                   queue_cap=int(d["queue_cap"]), tele=d["tele"])


# --------------------------------------------------------------------------
# capacity headroom report
# --------------------------------------------------------------------------

def parse_scales(scales_str: str) -> dict:
    """``tuner.format_scales`` rendering ("chase=1,sub=2,...") → dict."""
    out = {}
    for part in str(scales_str).replace(";", ",").split(","):
        if "=" in part:
            k, _, v = part.strip().partition("=")
            try:
                out[k.strip()] = float(v)
            except ValueError:
                pass
    return out


def headroom_rows(records: Iterable[StageRecord],
                  final_scales: str | None = None) -> list[dict]:
    """The capacity headroom report: one row per (stage, family, hop)
    that saw traffic — observed max fill / compiled cap, headroom, and
    the escalation factor the final scales applied to that family (so
    every escalation in ``scales_log`` terms is explained by the fill
    that forced it)."""
    scales = parse_scales(final_scales) if final_scales else {}
    rows = []
    for rec in records:
        for fam in STAGE_FAMILIES:
            tele = rec.tele.get(fam)
            caps = rec.caps.get(fam)
            if not tele or not caps or not int(tele.get("rounds", 0)):
                continue
            fills = tele.get("fill_max", [])
            for hop, fill in enumerate(fills):
                cap = int(caps[min(hop, len(caps) - 1)])
                rows.append({
                    "stage": rec.label, "level": rec.level, "family": fam,
                    "hop": hop, "cap": cap, "fill_max": float(fill),
                    "headroom": 1.0 - float(fill),
                    "scale": float(scales.get(fam, 1.0)),
                    "dest_frac_max": float(tele["dest_frac_max"][hop]),
                    "rounds": int(tele["rounds"]),
                })
        if rec.queue_cap and int(rec.tele.get("queue_hwm", 0)):
            hwm = int(rec.tele["queue_hwm"])
            rows.append({
                "stage": rec.label, "level": rec.level, "family": "queue",
                "hop": 0, "cap": int(rec.queue_cap),
                "fill_max": hwm / max(int(rec.queue_cap), 1),
                "headroom": 1.0 - hwm / max(int(rec.queue_cap), 1),
                "scale": 1.0, "dest_frac_max": 0.0,
                "rounds": int(rec.tele.get("queue_hwm", 0) and 1)})
    return rows


def format_headroom_table(rows: Sequence[Mapping]) -> str:
    """Aligned-text capacity headroom report (mirrors
    ``obs.format_residual_table``)."""
    if not rows:
        return "(no telemetry recorded — run with cfg.telemetry=True)"
    hdr = ("stage", "family", "hop", "cap", "fill_max", "headroom",
           "scale", "skew")
    body = [(r["stage"], r["family"], str(r["hop"]), str(r["cap"]),
             f"{r['fill_max']:.3f}", f"{r['headroom']:+.3f}",
             f"x{r['scale']:g}", f"{r['dest_frac_max']:.3f}")
            for r in rows]
    widths = [max(len(h), *(len(b[i]) for b in body))
              for i, h in enumerate(hdr)]
    fmt = "  ".join(f"{{:<{w}}}" if i < 2 else f"{{:>{w}}}"
                    for i, w in enumerate(widths))
    lines = [fmt.format(*hdr), fmt.format(*("-" * w for w in widths))]
    lines += [fmt.format(*b) for b in body]
    worst = max(rows, key=lambda r: r["fill_max"])
    lines.append(
        f"worst fill {worst['fill_max']:.3f} of cap {worst['cap']} "
        f"({worst['stage']}/{worst['family']} hop {worst['hop']})")
    return "\n".join(lines)


# --------------------------------------------------------------------------
# DKW back-test against tuner.estimate_capacities
# --------------------------------------------------------------------------

def dkw_margin(sample_size: int, n_buckets: int) -> float:
    """The DKW/Hoeffding additive margin ``tuner.estimate_capacities``
    adds to the hottest-bucket sample fraction (same formula)."""
    return math.sqrt(math.log(2.0 * n_buckets + 2.0)
                     / (2.0 * max(sample_size, 1)))


def dkw_backtest(max_frac: Sequence[float], sample_size: int,
                 hop_sizes: Sequence[int],
                 records: Iterable[StageRecord]) -> list[dict]:
    """Back-test the sampled-splitter estimate against observed fills.

    For each hop: the estimate's w.h.p. bound ``min(1, f_hat + margin)``
    on the hottest-bucket traffic fraction vs the worst
    ``dest_frac_max`` the telemetry actually observed across stages.
    ``ok`` means the observed skew stayed under the bound — the DKW
    margin held."""
    observed = {}
    for rec in records:
        for fam in STAGE_FAMILIES:
            tele = rec.tele.get(fam)
            if not tele or not int(tele.get("rounds", 0)):
                continue
            for hop, frac in enumerate(tele.get("dest_frac_max", [])):
                observed[hop] = max(observed.get(hop, 0.0), float(frac))
    rows = []
    for hop, (f_hat, s) in enumerate(zip(max_frac, hop_sizes)):
        margin = dkw_margin(sample_size, s)
        bound = min(1.0, float(f_hat) + margin)
        obs = observed.get(hop, 0.0)
        rows.append({"hop": hop, "hop_size": int(s),
                     "sampled_frac": float(f_hat), "margin": margin,
                     "bound": bound, "observed_frac": obs,
                     "ok": obs <= bound})
    return rows


__all__ = [
    "HIST_BINS", "MAX_KEYS", "STAGE_FAMILIES", "TELEMETRY_HELP",
    "route_zero", "stage_zero", "merge", "route_wave", "store_fill",
    "aggregate", "json_tele", "utilization", "StageRecord",
    "parse_scales", "headroom_rows", "format_headroom_table",
    "dkw_margin", "dkw_backtest",
]
