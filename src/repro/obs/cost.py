"""§2.6 model-vs-measured accounting: predicted time per stage.

Each traced stage carries a statically counted collective footprint
(``introspect.collective_footprint``: jaxpr collective primitive →
(count, payload bytes)). This module prices that footprint under the
active :class:`~repro.core.listrank.analysis.MachineModel`, so every
span gets a §2.6 predicted time next to its measured wall time and a
solve can emit a predicted-vs-observed residual table.

Pricing rule (the same alpha-beta decomposition as
:func:`analysis.t_all2all` / :func:`analysis.t_hops`):

- each counted ``all_to_all`` is one dense hop over its peer group.
  With a d-hop indirection the hops interleave in the jaxpr, so a
  single counted hop is priced at the *mean* hop size
  ``mean_h = (1/d) * sum_h hop_size(h)``; summing the d counted hops of
  one routing round recovers exactly the round's
  ``t_all2all``-style startup ``alpha * sum_h hop_size(h)``
  (= ``alpha * d * p^(1/d)`` on a balanced grid). Formally, one
  counted hop costs ``analysis.t_all2all(mean_h, words, d=1)``.
- tree collectives (``psum``/``all_gather``/etc. lowered as reductions)
  are priced as a log-depth tree: ``alpha * ceil(log2 p)`` startup plus
  ``beta * words`` volume.
- ``words = payload_bytes / 8`` (beta is per 8-byte word). Under the
  simshard backend, marker operands carry the virtual-PE batch axis, so
  recorded bytes are p× the per-PE payload; callers pass
  ``per_pe_scale = 1/p`` there (``predict_stage`` derives it from the
  mesh).

This is a *static* prediction — footprints are counted from the jaxpr,
never measured — so it is bitwise independent of execution and adds no
collectives of its own.
"""
from __future__ import annotations

import math

#: primitives priced as one dense hop of the indirection.
DENSE_HOP_PRIMS = ("all_to_all",)


def hop_sizes_of(plan) -> tuple[int, ...]:
    """Peer-group size of each indirection hop of a ``MeshPlan``."""
    return tuple(plan.hop_size(hop) for hop in plan.indirection.hops)


def predict_footprint(footprint: dict, p: int,
                      hop_sizes: tuple[int, ...],
                      machine: analysis.MachineModel,
                      per_pe_scale: float = 1.0) -> dict:
    """Price a collective footprint under the alpha-beta model.

    Args:
      footprint: ``{prim: (count, payload_bytes)}`` from
        ``introspect.collective_footprint``.
      p: total PE count (tree-collective depth is ``ceil(log2 p)``).
      hop_sizes: the indirection's per-hop peer-group sizes.
      machine: the active :class:`analysis.MachineModel`.
      per_pe_scale: multiply recorded bytes by this to get per-PE
        payload (``1/p`` under simshard, 1 on a real mesh).

    Returns:
      ``{"total_s": float, "by_prim": {prim: seconds},
         "startup_s": float, "volume_s": float}``.
    """
    d = max(len(hop_sizes), 1)
    mean_hop = (sum(hop_sizes) / d) if hop_sizes else float(p)
    log_p = math.ceil(math.log2(max(p, 2)))
    by_prim: dict[str, float] = {}
    startup = volume = 0.0
    for prim, (count, nbytes) in sorted(footprint.items()):
        words = nbytes * per_pe_scale / 8.0
        if prim in DENSE_HOP_PRIMS:
            # one counted hop == t_all2all over its peer group at d=1
            t_s = machine.alpha * mean_hop * count
        else:
            t_s = machine.alpha * log_p * count
        t_v = machine.beta * words
        by_prim[prim] = t_s + t_v
        startup += t_s
        volume += t_v
    return {"total_s": startup + volume, "by_prim": by_prim,
            "startup_s": startup, "volume_s": volume}


def predict_stage(footprint: dict, plan, machine: analysis.MachineModel,
                  sim: bool) -> dict:
    """Stage prediction from a ``MeshPlan`` (hop sizes + p) — the form
    the resume-loop instrumentation uses. ``sim`` selects the
    virtual-PE byte normalization (see module doc)."""
    return predict_footprint(
        footprint, plan.p, hop_sizes_of(plan), machine,
        per_pe_scale=(1.0 / plan.p) if sim else 1.0)


def predict_solve(n: int, plan, machine: analysis.MachineModel,
                  r_total: int | None = None) -> float:
    """Whole-solve §2.6 prediction (``analysis.t_hops`` over the plan's
    actual hop decomposition) — annotated on the root solve span for a
    coarse end-to-end residual alongside the per-stage ones."""
    # lazy: repro.obs must stay importable from anywhere in the core
    # without triggering the listrank package init (fault_tolerance ->
    # obs -> listrank -> resume -> fault_tolerance would cycle)
    from repro.core.listrank import analysis
    hop_sizes = hop_sizes_of(plan)
    machines = tuple(machine for _ in hop_sizes)
    if r_total is None:
        r_total = analysis.r_star(n, plan.p, max(len(hop_sizes), 1), machine)
    return analysis.t_hops(n, plan.p, max(r_total, 1), hop_sizes, machines)


# --------------------------------------------------------------------------
# measured-vs-modeled destination skew (telemetry plane)
# --------------------------------------------------------------------------

def skew_rows(hop_sizes, stage_records) -> list[dict]:
    """Measured-vs-modeled per-hop destination skew.

    The §2 capacity derivation models destinations as uniform: the
    hottest bucket of a hop with peer-group size ``s`` carries a
    ``1/s`` traffic fraction in expectation. The telemetry plane
    measures the worst ``dest_frac_max`` each hop actually saw — the
    ratio is the skew factor the capacity slack has to absorb, the
    residual-table counterpart for *capacities* instead of seconds.

    ``stage_records`` accepts both :class:`~repro.obs.telemetry.
    StageRecord` objects and their ``to_json`` dicts (the
    ``host_stats["telemetry"]["stages"]`` form).
    """
    from repro.obs import telemetry as tele_lib
    observed: dict[int, float] = {}
    for rec in stage_records:
        tele = rec.get("tele", {}) if isinstance(rec, dict) else rec.tele
        for fam in tele_lib.STAGE_FAMILIES:
            t = tele.get(fam)
            if not t or not int(t.get("rounds", 0)):
                continue
            for hop, frac in enumerate(t.get("dest_frac_max", [])):
                observed[hop] = max(observed.get(hop, 0.0), float(frac))
    rows = []
    for hop, s in enumerate(hop_sizes):
        modeled = 1.0 / max(int(s), 1)
        obs = observed.get(hop, 0.0)
        rows.append({"hop": hop, "hop_size": int(s),
                     "modeled_frac": modeled, "observed_frac": obs,
                     "skew": obs / modeled})
    return rows


def format_skew_table(rows, title: str | None = None) -> str:
    """Aligned text rendering of the per-hop skew rows."""
    header = ("hop", "size", "modeled", "observed", "skew")
    body = [(str(r["hop"]), str(r["hop_size"]),
             f"{r['modeled_frac']:.4f}", f"{r['observed_frac']:.4f}",
             f"{r['skew']:.2f}x") for r in rows]
    widths = [max(len(header[i]), *(len(b[i]) for b in body))
              if body else len(header[i]) for i in range(len(header))]
    lines = [] if title is None else [title]
    lines.append("  ".join(h.rjust(w) for h, w in zip(header, widths)))
    lines.append("  ".join("-" * w for w in widths))
    lines += ["  ".join(c.rjust(w) for c, w in zip(row, widths))
              for row in body]
    if not body:
        lines.append("(no telemetry recorded)")
    return "\n".join(lines)


def footprint_summary(footprint: dict) -> dict:
    """JSON-safe ``{prim: {"count": int, "bytes": int}}`` for span args."""
    return {prim: {"count": int(c), "bytes": int(b)}
            for prim, (c, b) in sorted(footprint.items())}


def total_collectives(footprint: dict) -> tuple[int, int]:
    """(total collective count, total payload bytes) of a footprint."""
    count = sum(int(c) for c, _ in footprint.values())
    nbytes = sum(int(b) for _, b in footprint.values())
    return count, nbytes


__all__ = ["DENSE_HOP_PRIMS", "hop_sizes_of", "predict_footprint",
           "predict_stage", "predict_solve", "footprint_summary",
           "total_collectives", "skew_rows", "format_skew_table"]
