"""Host-side span tracing for the solve path (the flight recorder).

A :class:`Tracer` records a tree of :class:`Span`\\ s — one per stage
execution, attempt, checkpoint save/restore, capacity-estimation
pre-pass, or front-door pipeline — with wall timings bounded by the
driver's existing ``jax.block_until_ready`` device syncs. Spans carry
arbitrary JSON-safe annotations (recursion level, attempt number, the
active :class:`~repro.core.listrank.tuner.CapacityScales`, the stage's
statically counted collective footprint, and the §2.6 predicted time).

The cardinal rule (DESIGN.md §12): **instrumentation never perturbs a
traced program.** The tracer is pure host python; it is never part of a
jit cache key, never closes over device values, and adds zero
collectives — a solve with tracing on reproduces the tracer-off bytes,
counters, and jaxpr collective counts exactly (pinned in
``tests/test_obs.py``).

When tracing is off, every instrumentation site goes through
:data:`NULL_TRACER`, whose ``span``/``begin`` return one shared
:data:`NULL_SPAN` singleton — no Span objects are allocated, no clock
is read (also pinned by test).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Iterator


@dataclasses.dataclass
class Span:
    """One recorded interval. Times are ``perf_counter`` seconds
    relative to the tracer's epoch; ``t1 is None`` while open."""
    name: str
    cat: str
    index: int                 #: creation order (stable tie-break)
    parent: int                #: index of the enclosing span, -1 at root
    depth: int                 #: nesting depth at open time
    t0: float
    t1: float | None = None
    args: dict = dataclasses.field(default_factory=dict)

    @property
    def duration(self) -> float:
        return (self.t1 if self.t1 is not None else self.t0) - self.t0

    def annotate(self, **kw) -> "Span":
        self.args.update(kw)
        return self

    # context-manager protocol is provided by the tracer-bound handle;
    # a bare Span is just the record.


class _SpanHandle:
    """A live span bound to its tracer — usable as a context manager
    (``with tracer.span(...) as sp``) or via explicit
    ``tracer.end(handle)``."""

    __slots__ = ("_tracer", "span")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self.span = span

    def annotate(self, **kw) -> "_SpanHandle":
        self.span.args.update(kw)
        return self

    def __enter__(self) -> "_SpanHandle":
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None and "outcome" not in self.span.args:
            self.span.args["outcome"] = exc_type.__name__
        self._tracer.end(self)
        return False


class _NullSpan:
    """The shared do-nothing span handle of :data:`NULL_TRACER`."""

    __slots__ = ()

    def annotate(self, **kw) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled tracer: every operation is a no-op returning the shared
    :data:`NULL_SPAN`. ``enabled`` gates any instrumentation work with
    a measurable cost (jaxpr tracing for footprints, registry updates).
    """

    enabled = False
    spans: tuple = ()
    counters: tuple = ()
    metrics = None

    def span(self, name: str, cat: str = "host", **args):
        return NULL_SPAN

    def begin(self, name: str, cat: str = "host", **args):
        return NULL_SPAN

    def end(self, handle, **args) -> None:
        pass

    def instant(self, name: str, cat: str = "host", **args) -> None:
        pass

    def counter(self, name: str, value, t: float | None = None) -> None:
        pass


NULL_TRACER = NullTracer()


def ensure(tracer) -> "Tracer | NullTracer":
    """Normalize an optional tracer argument: None -> the no-op
    singleton, anything else passed through."""
    return NULL_TRACER if tracer is None else tracer


class Tracer:
    """The recording tracer.

    ``meta`` rides into the Chrome-trace export as process metadata;
    ``metrics`` is an optional
    :class:`~repro.obs.metrics.MetricsRegistry` the instrumented
    drivers feed (one is created lazily on first use if not supplied).
    """

    enabled = True

    def __init__(self, meta: dict | None = None, metrics=None,
                 clock=time.perf_counter):
        self.meta = dict(meta or {})
        self._clock = clock
        self.epoch = clock()
        #: wall-clock time of the epoch (for trend records / trace meta)
        self.epoch_unix = time.time()
        self.spans: list[Span] = []
        self.instants: list[Span] = []
        #: counter-track samples: (name, t_seconds, float value) — the
        #: telemetry plane's utilization series (Perfetto "C" events).
        self.counters: list[tuple[str, float, float]] = []
        self._stack: list[_SpanHandle] = []
        self._metrics = metrics

    # ------------------------------------------------------------ metrics
    @property
    def metrics(self):
        if self._metrics is None:
            from repro.obs.metrics import MetricsRegistry
            self._metrics = MetricsRegistry()
        return self._metrics

    # -------------------------------------------------------------- spans
    def now(self) -> float:
        return self._clock() - self.epoch

    def begin(self, name: str, cat: str = "host", **args) -> _SpanHandle:
        parent = self._stack[-1].span.index if self._stack else -1
        span = Span(name=name, cat=cat, index=len(self.spans),
                    parent=parent, depth=len(self._stack), t0=self.now(),
                    args=dict(args))
        self.spans.append(span)
        handle = _SpanHandle(self, span)
        self._stack.append(handle)
        return handle

    def end(self, handle: _SpanHandle, **args) -> None:
        if isinstance(handle, _NullSpan):  # tolerate mixed call sites
            return
        handle.span.args.update(args)
        # close any forgotten children so the tree stays well-formed
        while self._stack:
            top = self._stack.pop()
            if top.span.t1 is None:
                top.span.t1 = self.now()
            if top is handle:
                return
        if handle.span.t1 is None:  # already off-stack (double end)
            handle.span.t1 = self.now()

    def span(self, name: str, cat: str = "host", **args) -> _SpanHandle:
        """``with tracer.span("base@2", cat="stage") as sp: ...``"""
        return self.begin(name, cat, **args)

    def instant(self, name: str, cat: str = "host", **args) -> None:
        """A zero-duration event (fault injections, preemptions, ...)."""
        parent = self._stack[-1].span.index if self._stack else -1
        t = self.now()
        self.instants.append(Span(name=name, cat=cat, index=-1,
                                  parent=parent, depth=len(self._stack),
                                  t0=t, t1=t, args=dict(args)))

    def counter(self, name: str, value, t: float | None = None) -> None:
        """Sample a counter track (mailbox utilization, queue HWM) at
        ``t`` (tracer-relative seconds; now() when omitted). Exported
        as Chrome "C" events — one track per name."""
        self.counters.append((name, self.now() if t is None else float(t),
                              float(value)))

    # ------------------------------------------------------------ queries
    def find(self, cat: str | None = None,
             name: str | None = None) -> Iterator[Span]:
        for s in self.spans:
            if cat is not None and s.cat != cat:
                continue
            if name is not None and s.name != name:
                continue
            yield s

    def children(self, span: Span) -> list[Span]:
        return [s for s in self.spans if s.parent == span.index]

    def close_all(self) -> None:
        """Close every span still open (end-of-process safety)."""
        while self._stack:
            self.end(self._stack[-1])


def span_tree_lines(tracer: Tracer) -> list[str]:
    """Human-readable indented rendering of the span tree (debugging)."""
    out = []
    for s in tracer.spans:
        dur = f"{s.duration * 1e3:8.2f}ms" if s.t1 is not None else "    open"
        out.append(f"{'  ' * s.depth}{s.name} [{s.cat}] {dur}")
    return out


def maybe(tracer, cond: bool) -> "Tracer | NullTracer":
    """``tracer`` when ``cond`` else the no-op singleton — lets call
    sites gate nested instrumentation without branching."""
    return tracer if cond else NULL_TRACER


__all__ = ["Span", "Tracer", "NullTracer", "NULL_TRACER", "NULL_SPAN",
           "ensure", "maybe", "span_tree_lines"]


_ = Any  # typing import kept for annotations above
