"""Unified metrics registry: the typed schema behind ``host_stats``.

The solver's host-facing counters historically rode in ad-hoc dicts
(``host_stats`` from the resume driver, ``rec``/``recovery`` from the
supervisor, graphalg's ``cc_*`` keys, bench JSON blobs). This module
gives them one schema — :class:`Counter` / :class:`Gauge` /
:class:`Histogram` / :class:`Text` in a :class:`MetricsRegistry` — plus
``ingest_host_stats`` to lift any solver stats dict into it, with help
strings sourced from the owning modules (``srs.STAT_HELP``,
``graphalg.cc.GRAPH_STAT_HELP``).

Also home to :func:`json_safe` — the canonical "make this stats value
JSON-serializable" conversion used by the bench workers and the
Chrome-trace exporter (host_stats now carries tuples and nested dicts,
which ``int()``-casting bench code used to choke on).
"""
from __future__ import annotations

import dataclasses
import math


# --------------------------------------------------------------------------
# metric types
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Counter:
    """Monotone event count (messages sent, rounds run, retries)."""
    name: str
    help: str = ""
    value: int = 0

    kind = "counter"

    def inc(self, v: int = 1) -> "Counter":
        if v < 0:
            raise ValueError(f"counter {self.name}: negative increment {v}")
        self.value += int(v)
        return self

    def snapshot(self) -> dict:
        return {"kind": self.kind, "help": self.help, "value": self.value}


@dataclasses.dataclass
class Gauge:
    """Last-observed level (max queue depth, resume index, scale)."""
    name: str
    help: str = ""
    value: float = 0.0

    kind = "gauge"

    def set(self, v: float) -> "Gauge":
        self.value = float(v)
        return self

    def snapshot(self) -> dict:
        return {"kind": self.kind, "help": self.help, "value": self.value}


@dataclasses.dataclass
class Histogram:
    """Streaming distribution summary (stage wall times, residuals).

    Keeps count/sum/min/max — enough for means and extremes without
    unbounded storage; the full per-span series lives in the trace.
    """
    name: str
    help: str = ""
    count: int = 0
    total: float = 0.0
    min: float = math.inf
    max: float = -math.inf

    kind = "histogram"

    def observe(self, v: float) -> "Histogram":
        v = float(v)
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        return self

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def snapshot(self) -> dict:
        return {"kind": self.kind, "help": self.help, "count": self.count,
                "sum": self.total, "mean": self.mean,
                "min": None if self.count == 0 else self.min,
                "max": None if self.count == 0 else self.max}


@dataclasses.dataclass
class Text:
    """Non-numeric annotation (escalation path, stage log)."""
    name: str
    help: str = ""
    value: str = ""

    kind = "text"

    def set(self, v: str) -> "Text":
        self.value = str(v)
        return self

    def snapshot(self) -> dict:
        return {"kind": self.kind, "help": self.help, "value": self.value}


class MetricsRegistry:
    """Name -> typed metric, get-or-create per kind. Re-registering a
    name with a different kind is an error (the schema is the point)."""

    def __init__(self):
        self._metrics: dict[str, object] = {}

    def _get(self, cls, name: str, help: str):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name=name, help=help)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is a {m.kind}, not "
                            f"{cls.kind}")
        elif help and not m.help:
            m.help = help
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._get(Histogram, name, help)

    def text(self, name: str, help: str = "") -> Text:
        return self._get(Text, name, help)

    def get(self, name: str):
        return self._metrics.get(name)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __iter__(self):
        return iter(self._metrics.values())

    def __len__(self) -> int:
        return len(self._metrics)

    def to_dict(self) -> dict:
        """The full registry as a JSON-safe ``{name: snapshot}`` dict."""
        return {name: m.snapshot()
                for name, m in sorted(self._metrics.items())}


# --------------------------------------------------------------------------
# host_stats ingestion
# --------------------------------------------------------------------------

#: host_stats keys that are levels, not event counts.
GAUGE_KEYS = ("max_queue", "sub_size", "rulers", "forest_edges")


def _stat_help() -> dict:
    """Help strings from the modules that own the stat keys (lazy to
    keep obs import-light and cycle-free)."""
    out: dict[str, str] = {}
    try:
        from repro.core.listrank import srs as srs_lib
        out.update(getattr(srs_lib, "STAT_HELP", {}))
    except Exception:  # pragma: no cover - core always importable
        pass
    try:
        from repro.core.graphalg import cc as cc_lib
        out.update(getattr(cc_lib, "GRAPH_STAT_HELP", {}))
    except Exception:  # pragma: no cover
        pass
    try:
        from repro.obs import telemetry as tele_lib
        out.update(getattr(tele_lib, "TELEMETRY_HELP", {}))
    except Exception:  # pragma: no cover
        pass
    return out


def ingest_host_stats(registry: MetricsRegistry, stats: dict,
                      prefix: str = "solve/") -> MetricsRegistry:
    """Lift a solver ``host_stats`` dict into the typed registry.

    Ints become counters (or gauges for :data:`GAUGE_KEYS`), strings
    become text metrics, ``stage_log`` becomes a stages-run counter plus
    its text form, and the ``recovery`` sub-dict maps to
    ``recovery/<key>`` counters/gauges with the injected-event list as
    text. Unknown shapes fall back to text via :func:`json_safe` —
    ingestion never raises on a new stat key.
    """
    import json
    help_of = _stat_help()
    for key, val in stats.items():
        name = prefix + key
        h = help_of.get(key, "")
        if key == "stage_log":
            registry.counter(prefix + "stages_run",
                             "stage executions recorded in stage_log"
                             ).inc(len(val))
            registry.text(name, h).set(";".join(val))
        elif key == "stage_collectives":
            registry.counter(prefix + "stage_collectives_recorded",
                             "stages with traced collective counts"
                             ).inc(len(val))
        elif key == "telemetry":
            # the device-telemetry sub-dict (stage records + headroom
            # report) -> utilization histograms and worst-fill gauges;
            # the full report stays in host_stats / the trace.
            stages = val.get("stages", []) if isinstance(val, dict) else []
            registry.counter(prefix + "telemetry/stages",
                             "stage records carrying device telemetry"
                             ).inc(len(stages))
            for rec in stages:
                registry.histogram(prefix + "telemetry/stage_util_max",
                                   help_of.get("util_max", "")
                                   ).observe(float(rec.get("util_max", 0.0)))
                registry.histogram(prefix + "telemetry/stage_util_mean",
                                   help_of.get("util_mean", "")
                                   ).observe(float(rec.get("util_mean", 0.0)))
            rows = val.get("headroom", []) if isinstance(val, dict) else []
            if rows:
                registry.gauge(prefix + "telemetry/worst_fill",
                               help_of.get("fill_max", "")
                               ).set(max(float(r.get("fill_max", 0.0))
                                         for r in rows))
            dkw = val.get("dkw", []) if isinstance(val, dict) else []
            if dkw:
                registry.counter(
                    prefix + "telemetry/dkw_violations",
                    "hops whose observed skew exceeded the DKW bound"
                    ).inc(sum(1 for r in dkw if not r.get("ok", True)))
        elif key == "recovery":
            for rk, rv in val.items():
                rname = prefix + "recovery/" + rk
                if rk == "resumed_from":
                    registry.gauge(rname,
                                   "schedule index restored from (-1: fresh)"
                                   ).set(rv)
                elif isinstance(rv, (bool, int)):
                    registry.counter(rname).inc(int(rv))
                else:
                    registry.text(rname).set(json.dumps(json_safe(rv)))
        elif isinstance(val, bool):
            registry.counter(name, h).inc(int(val))
        elif isinstance(val, int):
            if key in GAUGE_KEYS:
                registry.gauge(name, h).set(val)
            else:
                registry.counter(name, h).inc(val)
        elif isinstance(val, float):
            registry.gauge(name, h).set(val)
        elif isinstance(val, str):
            registry.text(name, h).set(val)
        else:
            registry.text(name, h).set(json.dumps(json_safe(val)))
    return registry


# --------------------------------------------------------------------------
# JSON-safe conversion
# --------------------------------------------------------------------------

def json_safe(obj):
    """Recursively convert a stats/annotation value to plain JSON types.

    Handles numpy scalars/arrays, jax arrays (via their numpy view),
    tuples, dataclasses (``CapacityScales`` in span args), and nested
    dicts. Unknown leaves degrade to ``repr`` rather than raising —
    exporters must never take down a solve.
    """
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if isinstance(obj, dict):
        return {str(k): json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [json_safe(v) for v in obj]
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return json_safe(dataclasses.asdict(obj))
    item = getattr(obj, "item", None)
    if callable(item):
        try:
            v = item()
            if isinstance(v, (bool, int, float, str)):
                return v
        except Exception:
            pass
    tolist = getattr(obj, "tolist", None)
    if callable(tolist):
        try:
            return json_safe(tolist())
        except Exception:
            pass
    return repr(obj)


def json_safe_stats(stats: dict) -> dict:
    """``host_stats`` -> a JSON-serializable dict (bench workers)."""
    return {str(k): json_safe(v) for k, v in stats.items()}


__all__ = ["Counter", "Gauge", "Histogram", "Text", "MetricsRegistry",
           "GAUGE_KEYS", "ingest_host_stats", "json_safe",
           "json_safe_stats"]
