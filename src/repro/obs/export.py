"""Exporters: Chrome-trace JSON (Perfetto-loadable) and the residual
table.

``chrome_trace`` serializes a :class:`~repro.obs.trace.Tracer` into the
Chrome trace-event format (the JSON array-of-events "traceEvents" form
that chrome://tracing and https://ui.perfetto.dev load directly):

- every span -> one complete ("ph": "X") event, microsecond ``ts``
  relative to the tracer epoch, ``dur`` from the device-sync-bounded
  wall time, ``cat`` from the span taxonomy (DESIGN.md §12), and the
  span's annotations (level, attempt, scales, collective footprint,
  predicted time) under ``args``;
- every instant (fault injections, preemptions, escalations) -> an
  "i" event with thread scope — the recovery timeline;
- tracer ``meta`` -> process_name / metadata events.

``residual_rows`` / ``format_residual_table`` turn the same spans into
the §2.6 model-vs-measured artifact: one row per stage attempt with
measured wall seconds, predicted seconds, the residual, and the
counted collective footprint.
"""
from __future__ import annotations

import json

from repro.obs.metrics import json_safe

_US = 1e6


def chrome_trace(tracer, pid: int = 0) -> dict:
    """The trace as a Chrome trace-event dict (``json.dump``-ready).

    Tolerates a :class:`~repro.obs.trace.NullTracer` (or any tracer
    missing attributes): the result is a minimal but valid trace —
    exporters must never take down a solve."""
    meta = getattr(tracer, "meta", None) or {}
    spans = getattr(tracer, "spans", ()) or ()
    instants = getattr(tracer, "instants", ()) or ()
    events = [{
        "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
        "args": {"name": meta.get("name", "repro-solve")},
    }]
    if meta:
        events.append({"ph": "M", "name": "process_labels", "pid": pid,
                       "tid": 0,
                       "args": {"labels": json.dumps(json_safe(meta))}})
    end_fallback = max((s.t1 for s in spans if s.t1 is not None),
                       default=0.0)
    for s in spans:
        t1 = s.t1 if s.t1 is not None else end_fallback
        events.append({
            "ph": "X", "name": s.name, "cat": s.cat, "pid": pid,
            "tid": s.depth,
            "ts": round(s.t0 * _US, 3),
            "dur": round(max(t1 - s.t0, 0.0) * _US, 3),
            "args": json_safe(s.args),
        })
    for s in instants:
        events.append({
            "ph": "i", "name": s.name, "cat": s.cat, "pid": pid,
            "tid": s.depth, "s": "t",
            "ts": round(s.t0 * _US, 3),
            "args": json_safe(s.args),
        })
    # counter tracks (telemetry utilization / queue HWM series); sorted
    # by time so each track's series is monotone in ts regardless of
    # which driver emitted the sample.
    for name, t, value in sorted(getattr(tracer, "counters", ()) or (),
                                 key=lambda c: c[1]):
        events.append({
            "ph": "C", "name": name, "cat": "telemetry", "pid": pid,
            "tid": 0,
            "ts": round(t * _US, 3),
            "args": {"value": float(value)},
        })
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"epoch_unix": getattr(tracer, "epoch_unix", 0.0),
                          **json_safe(meta)}}


def write_chrome_trace(tracer, path: str, pid: int = 0) -> str:
    """Write the Chrome trace JSON to ``path``; returns the path."""
    with open(path, "w") as f:
        json.dump(chrome_trace(tracer, pid=pid), f, indent=1)
    return path


# --------------------------------------------------------------------------
# model-vs-measured residuals
# --------------------------------------------------------------------------

def residual_rows(tracer) -> list[dict]:
    """One row per span carrying a §2.6 prediction (stage attempts and
    front-door pipeline attempts), in execution order."""
    rows = []
    for s in tracer.spans:
        if "predicted_s" not in s.args or s.t1 is None:
            continue
        measured = s.duration
        predicted = float(s.args["predicted_s"])
        rows.append({
            "stage": s.args.get("stage", s.name),
            "level": s.args.get("level", -1),
            "attempt": s.args.get("attempt", 1),
            "measured_s": measured,
            "predicted_s": predicted,
            "residual_s": measured - predicted,
            "ratio": (measured / predicted) if predicted > 0 else float("inf"),
            "collectives": s.args.get("collective_count", 0),
            "payload_bytes": s.args.get("payload_bytes", 0),
        })
    return rows


def format_residual_table(rows: list[dict], title: str | None = None) -> str:
    """Aligned text rendering of the per-stage residual table."""
    header = ("stage", "lvl", "try", "measured", "predicted", "residual",
              "ratio", "colls", "bytes")
    body = []
    for r in rows:
        body.append((
            str(r["stage"]), str(r["level"]), str(r["attempt"]),
            _fmt_s(r["measured_s"]), _fmt_s(r["predicted_s"]),
            _fmt_s(r["residual_s"]),
            ("inf" if r["ratio"] == float("inf") else f"{r['ratio']:.1f}x"),
            str(r["collectives"]), str(r["payload_bytes"])))
    widths = [max(len(header[i]), *(len(row[i]) for row in body))
              if body else len(header[i]) for i in range(len(header))]
    lines = [] if title is None else [title]
    lines.append("  ".join(h.ljust(w) for h, w in zip(header, widths)))
    lines.append("  ".join("-" * w for w in widths))
    for row in body:
        lines.append("  ".join(c.ljust(w) for c, w in zip(row, widths)))
    if not body:
        lines.append("(no predicted spans recorded)")
    return "\n".join(lines)


def _fmt_s(v: float) -> str:
    a = abs(v)
    if a >= 1.0:
        return f"{v:.3f}s"
    if a >= 1e-3:
        return f"{v * 1e3:.2f}ms"
    return f"{v * 1e6:.1f}us"


def residual_summary(rows: list[dict]) -> dict:
    """Headline numbers for trend records: totals and the worst
    per-stage over/under-prediction ratio."""
    if not rows:
        return {"stages": 0, "measured_s": 0.0, "predicted_s": 0.0}
    measured = sum(r["measured_s"] for r in rows)
    predicted = sum(r["predicted_s"] for r in rows)
    finite = [r["ratio"] for r in rows if r["ratio"] != float("inf")]
    return {
        "stages": len(rows),
        "measured_s": measured,
        "predicted_s": predicted,
        "total_ratio": (measured / predicted) if predicted > 0 else None,
        "max_ratio": max(finite) if finite else None,
        "min_ratio": min(finite) if finite else None,
    }


__all__ = ["chrome_trace", "write_chrome_trace", "residual_rows",
           "format_residual_table", "residual_summary"]
