"""Observability: span tracing, metrics, and §2.6 cost accounting.

The flight recorder for the solve path (DESIGN.md §12). Pass a
:class:`Tracer` to ``rank_list_with_stats(..., tracer=...)`` (or the
graphalg/treealg front doors) and every stage execution, retry,
checkpoint, and capacity-estimation pre-pass is recorded as a span with
its measured wall time, statically counted collective footprint, and
the §2.6 predicted time; export with
:func:`~repro.obs.export.write_chrome_trace` and
:func:`~repro.obs.export.format_residual_table`.

Instrumentation is host-side only and never perturbs a traced program —
the no-perturbation rule is pinned by ``tests/test_obs.py``.
"""
from repro.obs.trace import (NULL_TRACER, NullTracer, Span, Tracer, ensure,
                             span_tree_lines)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               Text, ingest_host_stats, json_safe,
                               json_safe_stats)
from repro.obs.cost import (footprint_summary, format_skew_table,
                            predict_footprint, predict_solve, predict_stage,
                            skew_rows, total_collectives)
from repro.obs.export import (chrome_trace, format_residual_table,
                              residual_rows, residual_summary,
                              write_chrome_trace)
from repro.obs.telemetry import (StageRecord, TELEMETRY_HELP, dkw_backtest,
                                 format_headroom_table, headroom_rows,
                                 utilization)

__all__ = [
    "Tracer", "NullTracer", "NULL_TRACER", "Span", "ensure",
    "span_tree_lines",
    "Counter", "Gauge", "Histogram", "Text", "MetricsRegistry",
    "ingest_host_stats", "json_safe", "json_safe_stats",
    "predict_footprint", "predict_stage", "predict_solve",
    "footprint_summary", "total_collectives",
    "skew_rows", "format_skew_table",
    "chrome_trace", "write_chrome_trace", "residual_rows",
    "format_residual_table", "residual_summary",
    "StageRecord", "TELEMETRY_HELP", "dkw_backtest",
    "format_headroom_table", "headroom_rows", "utilization",
]
