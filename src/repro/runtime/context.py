"""Ambient mesh context for layers that build shard_map regions.

Pure-functional model code cannot take a Mesh argument everywhere, so
drivers (train / dryrun / serve) activate the mesh around tracing:

    with runtime_context.use_mesh(mesh):
        jitted.lower(...)

``layers.moe_ffn`` switches to the expert-parallel shard_map path when
a context is active; without one it uses the single-program dispatch
(single-device tests, smoke configs).
"""
from __future__ import annotations

import contextlib
import dataclasses
from contextvars import ContextVar

import jax


@dataclasses.dataclass(frozen=True)
class MeshCtx:
    mesh: jax.sharding.Mesh
    dp_axes: tuple[str, ...]      # batch-parallel axes ("pod","data")
    ep_axis: str                  # expert-parallel axis ("data")
    tp_axis: str | None           # tensor-parallel axis ("model")

    @property
    def all_axes(self) -> tuple[str, ...]:
        return tuple(self.mesh.axis_names)


_CTX: ContextVar[MeshCtx | None] = ContextVar("repro_mesh_ctx", default=None)


def current() -> MeshCtx | None:
    return _CTX.get()


@contextlib.contextmanager
def use_mesh(mesh, dp_axes=None, ep_axis="data", tp_axis="model"):
    names = tuple(mesh.axis_names)
    if dp_axes is None:
        dp_axes = tuple(a for a in ("pod", "data") if a in names)
    tp = tp_axis if tp_axis in names else None
    ep = ep_axis if ep_axis in names else names[-1]
    tok = _CTX.set(MeshCtx(mesh=mesh, dp_axes=tuple(dp_axes), ep_axis=ep,
                           tp_axis=tp))
    try:
        yield
    finally:
        _CTX.reset(tok)
