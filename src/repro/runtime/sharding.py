"""Logical-axis sharding: rules, divisibility-aware resolution.

Every parameter / activation carries a tuple of *logical* axis names
(e.g. ("vocab", "embed")). A rule table maps logical names to ordered
candidate mesh-axis tuples; the resolver picks the first candidate whose
size divides the dimension and whose mesh axes are still unused in the
spec, else downgrades to replicated. All downgrades are recorded so the
dry-run can report exactly how each tensor ended up sharded.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat

#: default rule table. Each logical axis maps to candidates in
#: preference order; () means replicated.
DEFAULT_RULES: dict[str, tuple[tuple[str, ...], ...]] = {
    "batch": (("pod", "data"), ("data",), ()),
    "seq": ((),),
    "embed": ((),),
    "qkv_features": (("model",), ()),
    "kv_features": (("model",), ()),
    "heads": (("model",), ()),
    "kv_heads": (("model",), ()),
    "head_dim": (("model",), ()),
    "mlp": (("model",), ()),
    "vocab": (("model",), ()),
    "experts": (("data",), ("model",), ()),
    "expert_mlp": (("model",), ()),
    "conv": ((),),
    "ssm_state": ((),),
    "ssm_heads": (("model",), ()),
    "layers": ((),),
    "kv_seq": ((),),
    # sequence-parallel candidates (enabled by perf configs)
    "seq_sp": (("data",), ()),
}


@dataclasses.dataclass
class ResolveReport:
    """Per-tensor record of the chosen spec and any downgrades."""
    chosen: dict[str, P] = dataclasses.field(default_factory=dict)
    downgrades: list[str] = dataclasses.field(default_factory=list)


def resolve_spec(shape: Sequence[int], axes: Sequence[str | None],
                 mesh: Mesh, rules: Mapping[str, tuple] | None = None,
                 name: str = "", report: ResolveReport | None = None) -> P:
    """Pick a PartitionSpec for ``shape`` with logical ``axes``."""
    rules = rules or DEFAULT_RULES
    mesh_axes = set(mesh.axis_names)
    used: set[str] = set()
    parts: list = []
    for dim, ax in zip(shape, axes):
        if ax is None:
            parts.append(None)
            continue
        candidates = rules.get(ax, ((),))
        placed = None
        for cand in candidates:
            cand = tuple(a for a in cand if a in mesh_axes)
            if not cand:
                placed = None
                break
            size = 1
            for a in cand:
                size *= mesh.shape[a]
            if dim % size == 0 and not (set(cand) & used):
                placed = cand
                used |= set(cand)
                break
        else:
            placed = None
        if placed:
            parts.append(placed if len(placed) > 1 else placed[0])
        else:
            parts.append(None)
            if report is not None and rules.get(ax, ((),))[0]:
                first = tuple(a for a in rules.get(ax, ((),))[0]
                              if a in mesh_axes)
                if first:
                    report.downgrades.append(
                        f"{name}[{ax}]: {dim} not divisible/available for "
                        f"{first} -> replicated")
    spec = P(*parts)
    if report is not None:
        report.chosen[name] = spec
    return spec


def tree_shardings(spec_tree, mesh: Mesh, rules=None,
                   report: ResolveReport | None = None):
    """Map a tree of ParamSpec-likes (.shape/.axes) to NamedShardings."""
    flat, treedef = compat.tree_flatten_with_path(
        spec_tree, is_leaf=lambda x: hasattr(x, "axes"))
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        pspec = resolve_spec(leaf.shape, leaf.axes, mesh, rules, name, report)
        out.append(NamedSharding(mesh, pspec))
    return jax.tree.unflatten(treedef, out)
