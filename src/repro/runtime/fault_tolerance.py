"""Fault tolerance: supervised step loop with checkpoint/restart,
preemption handling, and straggler detection.

``Supervisor`` wraps the training loop of ``repro.launch.train``:

  - periodic (async) checkpoints via ``repro.checkpoint``;
  - crash/restart: any exception in a step triggers restore-from-latest
    and replay (the data pipeline is stateless in step, so batches
    regenerate exactly);
  - preemption: SIGTERM/SIGINT set a flag; the loop checkpoints and
    exits cleanly (what a TPU maintenance event needs);
  - straggler mitigation: per-step wall times feed a rolling median;
    steps slower than ``straggler_factor``x median are logged and
    counted. On a real pod this signal drives hot-spare pod swap /
    re-sharding via the elastic restore path (Checkpointer.restore
    re-shards to whatever mesh the restarted job has — demonstrated in
    tests/test_fault_tolerance.py by shrinking the mesh mid-run);
  - failure injection for tests (``inject_failure_at``).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from collections import deque
from typing import Callable

from repro.checkpoint import Checkpointer


@dataclasses.dataclass
class SupervisorConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    max_restarts: int = 3
    straggler_factor: float = 3.0
    straggler_window: int = 32
    async_save: bool = True


class Preempted(Exception):
    pass


class Supervisor:
    def __init__(self, cfg: SupervisorConfig, init_state: Callable[[], tuple],
                 restore_like: Callable[[], tuple], shardings=None):
        """init_state() -> (state, step0) builds fresh state;
        restore_like() -> abstract tree matching the checkpoint layout."""
        self.cfg = cfg
        self.ckpt = Checkpointer(cfg.ckpt_dir, keep=cfg.keep,
                                 async_save=cfg.async_save)
        self._init_state = init_state
        self._restore_like = restore_like
        self._shardings = shardings
        self._preempted = False
        self._times: deque[float] = deque(maxlen=cfg.straggler_window)
        self.stats = {"restarts": 0, "stragglers": 0, "preempted": False,
                      "checkpoints": 0}
        self.inject_failure_at: int | None = None

    def install_signal_handlers(self):
        for sig in (signal.SIGTERM, signal.SIGINT):
            signal.signal(sig, self._on_signal)

    def _on_signal(self, *_):
        self._preempted = True

    def _start_state(self):
        if self.ckpt.latest_step() is not None:
            state, step = self.ckpt.restore(None, self._restore_like(),
                                            self._shardings)
            return state, step
        return self._init_state()

    def _note_time(self, dt: float):
        if len(self._times) >= 8:
            med = sorted(self._times)[len(self._times) // 2]
            if dt > self.cfg.straggler_factor * med:
                self.stats["stragglers"] += 1
        self._times.append(dt)

    def run(self, step_fn: Callable, num_steps: int, on_metrics=None):
        """Run ``step_fn(state, step) -> (state, metrics)`` to
        ``num_steps`` with checkpoint/restart supervision."""
        restarts = 0
        state, step = self._start_state()
        while step < num_steps:
            try:
                if self._preempted:
                    raise Preempted()
                if self.inject_failure_at is not None \
                        and step == self.inject_failure_at:
                    self.inject_failure_at = None
                    raise RuntimeError("injected failure")
                t0 = time.time()
                state, metrics = step_fn(state, step)
                self._note_time(time.time() - t0)
                step += 1
                if on_metrics:
                    on_metrics(step, metrics)
                if step % self.cfg.ckpt_every == 0 or step == num_steps:
                    self.ckpt.save(step, state)
                    self.stats["checkpoints"] += 1
            except Preempted:
                self.ckpt.save(step, state, blocking=True)
                self.stats["preempted"] = True
                return state, step
            except Exception:
                restarts += 1
                self.stats["restarts"] = restarts
                if restarts > self.cfg.max_restarts:
                    raise
                latest = self.ckpt.latest_step()
                if latest is None:
                    state, step = self._init_state()
                else:
                    state, step = self.ckpt.restore(
                        None, self._restore_like(), self._shardings)
        self.ckpt.wait()
        return state, step
