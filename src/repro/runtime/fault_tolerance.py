"""Fault tolerance: supervised step loop with checkpoint/restart,
preemption handling, and straggler detection.

``Supervisor`` wraps the training loop of ``repro.launch.train``:

  - periodic (async) checkpoints via ``repro.checkpoint``;
  - crash/restart: any exception in a step triggers restore-from-latest
    and replay (the data pipeline is stateless in step, so batches
    regenerate exactly);
  - preemption: SIGTERM/SIGINT set a flag; the loop checkpoints and
    exits cleanly (what a TPU maintenance event needs);
  - straggler mitigation: per-step wall times feed a rolling median;
    steps slower than ``straggler_factor``x median are logged and
    counted. On a real pod this signal drives hot-spare pod swap /
    re-sharding via the elastic restore path (Checkpointer.restore
    re-shards to whatever mesh the restarted job has — demonstrated in
    tests/test_fault_tolerance.py by shrinking the mesh mid-run);
  - failure injection for tests (``inject_failure_at``).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from collections import deque
from typing import Callable

from repro.checkpoint import Checkpointer
from repro.obs.trace import NULL_TRACER


@dataclasses.dataclass
class SupervisorConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    max_restarts: int = 3
    straggler_factor: float = 3.0
    straggler_window: int = 32
    async_save: bool = True


@dataclasses.dataclass
class SolveSupervisorConfig(SupervisorConfig):
    """Supervisor defaults for the list-ranking solver's staged attempt
    loop: a solve has few (tens of) stage boundaries, so checkpoint at
    every level boundary rather than every 50 training steps."""
    ckpt_every: int = 1


class Preempted(Exception):
    pass


class Supervisor:
    def __init__(self, cfg: SupervisorConfig, init_state: Callable[[], tuple],
                 restore_like: Callable[[], tuple], shardings=None):
        """init_state() -> (state, step0) builds fresh state;
        restore_like() -> abstract tree matching the checkpoint layout."""
        self.cfg = cfg
        self.ckpt = Checkpointer(cfg.ckpt_dir, keep=cfg.keep,
                                 async_save=cfg.async_save)
        self._init_state = init_state
        self._restore_like = restore_like
        self._shardings = shardings
        self._preempted = False
        self._times: deque[float] = deque(maxlen=cfg.straggler_window)
        self.stats = {"restarts": 0, "stragglers": 0, "preempted": False,
                      "checkpoints": 0}
        self.inject_failure_at: int | None = None

    def install_signal_handlers(self):
        for sig in (signal.SIGTERM, signal.SIGINT):
            signal.signal(sig, self._on_signal)

    def _on_signal(self, *_):
        self._preempted = True

    def _start_state(self):
        if self.ckpt.latest_step() is not None:
            state, step = self.ckpt.restore(None, self._restore_like(),
                                            self._shardings)
            return state, step
        return self._init_state()

    def _note_time(self, dt: float):
        if len(self._times) >= 8:
            med = sorted(self._times)[len(self._times) // 2]
            if dt > self.cfg.straggler_factor * med:
                self.stats["stragglers"] += 1
        self._times.append(dt)

    def run(self, step_fn: Callable, num_steps: int, on_metrics=None):
        """Run ``step_fn(state, step) -> (state, metrics)`` to
        ``num_steps`` with checkpoint/restart supervision."""
        restarts = 0
        state, step = self._start_state()
        while step < num_steps:
            try:
                if self._preempted:
                    raise Preempted()
                if self.inject_failure_at is not None \
                        and step == self.inject_failure_at:
                    self.inject_failure_at = None
                    raise RuntimeError("injected failure")
                t0 = time.time()
                state, metrics = step_fn(state, step)
                self._note_time(time.time() - t0)
                step += 1
                if on_metrics:
                    on_metrics(step, metrics)
                if step % self.cfg.ckpt_every == 0 or step == num_steps:
                    self.ckpt.save(step, state)
                    self.stats["checkpoints"] += 1
            except Preempted:
                self.ckpt.save(step, state, blocking=True)
                self.stats["preempted"] = True
                return state, step
            except Exception:
                restarts += 1
                self.stats["restarts"] = restarts
                if restarts > self.cfg.max_restarts:
                    raise
                latest = self.ckpt.latest_step()
                if latest is None:
                    state, step = self._init_state()
                else:
                    state, step = self.ckpt.restore(
                        None, self._restore_like(), self._shardings)
        self.ckpt.wait()
        return state, step


class SolveSupervisor:
    """The :class:`Supervisor` adapted to the list-ranking solver's
    level-resumable stage loop (``repro.core.listrank.resume``).

    Unlike the training supervisor, the step loop lives in the solver
    driver (stages have heterogeneous state structures that only the
    driver can rebuild); this class owns the supervision concerns the
    driver delegates:

      - the :class:`~repro.checkpoint.Checkpointer` (atomic keep-k,
        async) with per-boundary cadence (``cfg.ckpt_every``, default
        every level boundary);
      - SIGTERM/SIGINT preemption flag (``install_signal_handlers`` /
        :attr:`preempted`); the driver writes a blocking checkpoint and
        raises :class:`Preempted`;
      - restart accounting (``should_retry``) and straggler detection
        over per-stage wall times;
      - ``stats`` threaded into the solver's ``host_stats["recovery"]``
        (restarts, stragglers, checkpoints, preempted, resumed_from).

    Checkpoints store the boundary-state pytree as full host arrays plus
    a manifest ``meta`` (schedule index, per-level capacity scales,
    attempt/escalation path, instance fingerprint), so a solve
    checkpointed on the 8-device mesh restores under simshard at any
    point and vice versa — the driver validates the fingerprint and
    re-places leaves for whatever backend it is running on.
    """

    def __init__(self, cfg: SupervisorConfig | None = None):
        self.cfg = cfg or SolveSupervisorConfig()
        self.ckpt = Checkpointer(self.cfg.ckpt_dir, keep=self.cfg.keep,
                                 async_save=self.cfg.async_save)
        self._preempted = False
        self._restarts = 0
        self._times: deque[float] = deque(maxlen=self.cfg.straggler_window)
        self.stats = {"restarts": 0, "stragglers": 0, "checkpoints": 0,
                      "preempted": 0, "resumed_from": -1}
        #: flight-recorder hook: the solve driver installs its tracer
        #: here so checkpoint save/restore appear in the span tree.
        self.tracer = NULL_TRACER

    # ---------------------------------------------------------- signals
    def install_signal_handlers(self):
        for sig in (signal.SIGTERM, signal.SIGINT):
            signal.signal(sig, self._on_signal)

    def _on_signal(self, *_):
        self._preempted = True

    def preempt(self):
        """Set the preemption flag (what a SIGTERM does); test hook and
        the target of the ``preempt`` fault injection."""
        self._preempted = True

    @property
    def preempted(self) -> bool:
        return self._preempted

    # ------------------------------------------------------ checkpoints
    def boundary(self, idx: int, state, meta: dict, blocking: bool = False):
        """Record a completed stage boundary; checkpoints on the
        ``ckpt_every`` cadence (or unconditionally when blocking)."""
        if blocking or idx % max(self.cfg.ckpt_every, 1) == 0:
            with self.tracer.span(f"ckpt-save@{idx}", cat="checkpoint",
                                  idx=idx, blocking=blocking):
                self.ckpt.save(idx, state, blocking=blocking, meta=meta)
            self.stats["checkpoints"] += 1

    def latest_meta(self) -> dict | None:
        """The manifest ``meta`` of the latest checkpoint, or None."""
        if self.ckpt.latest_step() is None:
            return None
        return self.ckpt.manifest().get("meta")

    def restore(self, like, shardings=None):
        with self.tracer.span("ckpt-restore", cat="checkpoint") as sp:
            out = self.ckpt.restore(None, like, shardings)
            sp.annotate(step=out[1] if isinstance(out, tuple) else None)
            return out

    # ------------------------------------------------------- accounting
    def note_stage_time(self, dt: float):
        if len(self._times) >= 8:
            med = sorted(self._times)[len(self._times) // 2]
            if dt > self.cfg.straggler_factor * med:
                self.stats["stragglers"] += 1
        self._times.append(dt)

    def should_retry(self) -> bool:
        """Account one crash/corruption recovery; False once the restart
        budget is exhausted."""
        self._restarts += 1
        self.stats["restarts"] = self._restarts
        return self._restarts <= self.cfg.max_restarts
