"""Blockwise int8 compression: optimizer-state quantization and
error-feedback compressed gradient all-reduce.

``QInt8`` is a pytree-registered container holding int8 payload plus
per-block fp32 scales (block = 256 contiguous elements, bitsandbytes
style). Used by:
  - AdamW ``state_dtype='int8'`` (4x optimizer memory cut — what makes
    the 1T kimi-k2 config trainable on a 512-chip v5e footprint),
  - ``compressed_psum`` — an error-feedback int8 gradient all-reduce
    for shard_map data-parallel loops (examples/dp_compression.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

BLOCK = 256


@partial(jax.tree_util.register_dataclass,
         data_fields=("q", "scale"), meta_fields=("shape",))
@dataclasses.dataclass
class QInt8:
    q: jax.Array        # (nblocks, BLOCK) int8
    scale: jax.Array    # (nblocks,) float32
    shape: tuple[int, ...]

    @staticmethod
    def _padded(n):
        return -(-n // BLOCK) * BLOCK

    @staticmethod
    def zeros(shape):
        n = 1
        for d in shape:
            n *= d
        nb = QInt8._padded(n) // BLOCK
        return QInt8(q=jnp.zeros((nb, BLOCK), jnp.int8),
                     scale=jnp.zeros((nb,), jnp.float32), shape=tuple(shape))

    @staticmethod
    def quantize(x: jax.Array) -> "QInt8":
        shape = x.shape
        flat = x.astype(jnp.float32).reshape(-1)
        pad = QInt8._padded(flat.size) - flat.size
        flat = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
        scale = jnp.max(jnp.abs(flat), axis=-1) / 127.0
        safe = jnp.where(scale > 0, scale, 1.0)
        q = jnp.clip(jnp.round(flat / safe[:, None]), -127, 127).astype(jnp.int8)
        return QInt8(q=q, scale=scale, shape=tuple(shape))

    def dequantize(self) -> jax.Array:
        flat = self.q.astype(jnp.float32) * self.scale[:, None]
        n = 1
        for d in self.shape:
            n *= d
        return flat.reshape(-1)[:n].reshape(self.shape)


def quantization_error(x: jax.Array) -> jax.Array:
    """x - dequantize(quantize(x)) — the residual error feedback keeps."""
    return x - QInt8.quantize(x).dequantize()


def compressed_psum(x: jax.Array, axis_name, error: jax.Array):
    """Error-feedback int8 all-reduce (inside shard_map).

    Returns (reduced fp32 approx of psum(x), new_error). The residual
    from quantization is carried and re-added next call, so the bias
    vanishes over steps (Karimireddy et al., error feedback)."""
    xc = x.astype(jnp.float32) + error
    q = QInt8.quantize(xc)
    deq = q.dequantize()
    new_error = xc - deq
    # the wire format is int8 payload + fp32 scales: reduce the
    # dequantized blocks (ICI reduces in fp; payload stays 1/4 size on
    # the wire when using scale-then-sum two-phase exchange)
    reduced = jax.lax.psum(deq, axis_name)
    return reduced, new_error
