from repro.kernels.local_chase import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
