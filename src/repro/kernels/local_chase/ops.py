"""Public wrapper for local_chase: dispatch between the Pallas VMEM
kernel and the XLA fallback, with the interpret-mode switch for CPU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.local_chase import kernel as _kernel
from repro.kernels.local_chase import ref as _ref

#: per-core VMEM budget for the resident working set (succ+dist, bytes).
VMEM_BUDGET = 12 * 1024 * 1024


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def local_chase(succ: jax.Array, dist: jax.Array, steps: int):
    """Wyllie doubling with self-absorbing stops; returns (succ, dist).

    Uses the Pallas VMEM kernel when the working set fits; interpret
    mode on CPU (this container), compiled on a real TPU.
    """
    m = succ.shape[-1]
    itemsize = jnp.dtype(succ.dtype).itemsize + jnp.dtype(dist.dtype).itemsize
    if m * itemsize <= VMEM_BUDGET:
        return _kernel.local_chase_pallas(succ, dist, steps,
                                          interpret=not _on_tpu())
    return _ref.local_chase_ref(succ, dist, steps)
