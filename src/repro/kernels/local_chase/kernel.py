"""Pallas TPU kernel: in-VMEM vectorized pointer doubling.

The paper's local contraction (§2.3) chases PE-local chains sequentially
in O(m) scalar steps. A TPU has no fast scalar loop over HBM, but its
VPU executes 8x128-lane vector ops — so we replace the scalar chase with
log2(m) *vectorized* Wyllie iterations executed entirely in VMEM:

  dist <- dist + dist[succ];  succ <- succ[succ]

Each iteration is two VMEM dynamic gathers + one add over the full local
array. The whole working set (succ + dist, 2 x 4B x m) stays resident in
VMEM: m up to ~1M elements fits the ~16MB v5e VMEM. Larger arrays fall
back to the XLA path in ops.py (HBM-streaming gathers).

Grid: one program per batch row (independent chases); each program owns
the full (m,) vectors — BlockSpec pins the whole row in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _chase_kernel(succ_ref, dist_ref, out_succ_ref, out_dist_ref, *, steps: int):
    s = succ_ref[...]
    d = dist_ref[...]

    def body(_, sd):
        s, d = sd
        # VMEM dynamic gather along the lane dimension
        s2 = jnp.take(s, s, axis=0)
        d2 = d + jnp.take(d, s, axis=0)
        return s2, d2

    s, d = jax.lax.fori_loop(0, steps, body, (s, d))
    out_succ_ref[...] = s
    out_dist_ref[...] = d


@functools.partial(jax.jit, static_argnames=("steps", "interpret"))
def local_chase_pallas(succ: jax.Array, dist: jax.Array, steps: int,
                       interpret: bool = True):
    """(B, m) batched in-VMEM pointer doubling. See module docstring."""
    if succ.ndim == 1:
        return jax.tree.map(
            lambda x: x[0],
            local_chase_pallas(succ[None], dist[None], steps, interpret))
    b, m = succ.shape
    kernel = functools.partial(_chase_kernel, steps=steps)
    out_shapes = (
        jax.ShapeDtypeStruct((b, m), succ.dtype),
        jax.ShapeDtypeStruct((b, m), dist.dtype),
    )
    # one batch row per program; the full row lives in VMEM
    row = pl.BlockSpec((None, m), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=(row, row),
        out_specs=(row, row),
        out_shape=out_shapes,
        interpret=interpret,
    )(succ, dist)
