"""Pure-jnp oracle for the local_chase kernel.

Wyllie pointer doubling over a PE-local index space with self-absorbing
stop elements:
  dist <- dist + dist[succ];  succ <- succ[succ]   (x ``steps``)

With stop elements encoded as self-loops carrying dist 0, after
ceil(log2(max chain length)) steps every element holds
  succ = index of its chain's stop element,
  dist = weighted distance to that stop element.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def local_chase_ref(succ: jax.Array, dist: jax.Array, steps: int):
    """succ: (..., m) int32 local indices; dist: (..., m) weights."""
    def body(_, sd):
        s, d = sd
        return (jnp.take_along_axis(s, s, axis=-1),
                d + jnp.take_along_axis(d, s, axis=-1))

    return jax.lax.fori_loop(0, steps, body, (succ, dist))


def sequential_chase_ref(succ, dist):
    """O(m) numpy pointer chasing oracle (ground truth for both the
    kernel and the jnp doubling)."""
    import numpy as np
    succ = np.asarray(succ)
    dist = np.asarray(dist)
    m = succ.shape[-1]
    out_s = np.empty_like(succ)
    out_d = np.empty_like(dist)
    flat_s = succ.reshape(-1, m)
    flat_d = dist.reshape(-1, m)
    fo_s = out_s.reshape(-1, m)
    fo_d = out_d.reshape(-1, m)
    for b in range(flat_s.shape[0]):
        s, d = flat_s[b], flat_d[b]
        for i in range(m):
            cur, acc = i, 0
            while s[cur] != cur:
                acc += d[cur]
                cur = s[cur]
            fo_s[b, i] = cur
            fo_d[b, i] = acc
    return out_s.reshape(succ.shape), out_d.reshape(dist.shape)
