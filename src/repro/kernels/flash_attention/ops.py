"""Public attention op: Pallas flash attention with an XLA fallback and
a custom-vjp backward (recompute-based) so it is usable in training.

The forward runs the Pallas kernel (interpret mode on CPU); the backward
uses the pure-jnp reference (XLA fuses it adequately; a dedicated bwd
kernel is a further optimization documented in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as _kernel
from repro.kernels.flash_attention import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention(q, k, v, causal=True, window=None, softcap=None,
                    scale=None, q_offset=0, use_pallas=True):
    """Attention with GQA/causal/window/softcap. q: (B,Hq,Lq,D)."""
    if use_pallas:
        return _kernel.flash_attention_pallas(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, q_offset=q_offset, interpret=not _on_tpu())
    return _ref.attention_ref(q, k, v, causal=causal, window=window,
                              softcap=softcap, scale=scale, q_offset=q_offset)


def _fwd(q, k, v, causal, window, softcap, scale, q_offset, use_pallas):
    out = flash_attention(q, k, v, causal, window, softcap, scale, q_offset,
                          use_pallas)
    return out, (q, k, v)


def _bwd(causal, window, softcap, scale, q_offset, use_pallas, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: _ref.attention_ref(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, q_offset=q_offset), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
