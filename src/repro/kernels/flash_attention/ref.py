"""Pure-jnp oracle: multi-head attention with GQA, causal masking,
sliding windows, and logit soft-capping (Gemma-2 style)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int | None = None,
                  softcap: float | None = None, scale: float | None = None,
                  q_offset: int = 0) -> jax.Array:
    """Reference attention.

    Args:
      q: (B, Hq, Lq, D); k/v: (B, Hkv, Lk, D) with Hq % Hkv == 0.
      causal: apply causal mask (q position >= k position).
      window: sliding-window size (attend to the last ``window`` keys).
      softcap: logit soft-capping cap*tanh(s/cap).
      scale: logit scale (default 1/sqrt(D)).
      q_offset: absolute position of q[0] (decode: kv_len - q_len);
        scalar or per-batch (B,) array (heterogeneous decode slots).
    """
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    qf = q.astype(jnp.float32)
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qo = jnp.asarray(q_offset)
    if qo.ndim == 1:  # per-batch offsets -> (B, 1, lq, lk) mask
        q_pos = qo[:, None, None, None] + jnp.arange(lq)[:, None]
        k_pos = jnp.arange(lk)[None, :]
        mask = jnp.ones((b, 1, lq, lk), jnp.bool_)
    else:
        q_pos = qo + jnp.arange(lq)[:, None]
        k_pos = jnp.arange(lk)[None, :]
        mask = jnp.ones((lq, lk), jnp.bool_)
    if causal:
        mask = mask & (q_pos >= k_pos)
    if window is not None:
        mask = mask & (q_pos - k_pos < window)
    if mask.ndim == 2:
        mask = mask[None, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # rows that attend to nothing (fully masked) produce zeros
    any_valid = mask.any(axis=-1)[..., None]
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vf)
    out = jnp.where(any_valid, out, 0.0)
    return out.astype(q.dtype)
