"""Pallas TPU kernel: blockwise flash attention (fwd) with GQA,
causal/sliding-window masking and logit soft-capping.

TPU mapping: grid = (batch, q_heads, q_blocks, kv_blocks) with the
kv-block dimension minor — TPU executes the grid sequentially, so the
running-softmax state (m, l, acc) lives in VMEM scratch and carries
across kv steps (the standard TPU flash-attention schedule; the
HBM->VMEM block streaming replaces the GPU's SMEM tiling).

BlockSpecs pin one (block_q, d) query tile and one (block_k, d) KV tile
in VMEM per step; the GQA index map folds the q-head -> kv-head mapping
into the K/V block fetch, so grouped heads re-stream the same KV tile
instead of materializing repeated heads in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int | None,
                  softcap: float | None, q_offset: int, n_kv: int,
                  lq_valid: int, lk_valid: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    blq, d = q_ref.shape
    blk = k_ref.shape[0]

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = q_offset + iq * blq + jax.lax.broadcasted_iota(jnp.int32, (blq, blk), 0)
    k_pos = ik * blk + jax.lax.broadcasted_iota(jnp.int32, (blq, blk), 1)
    mask = (q_pos < q_offset + lq_valid) & (k_pos < lk_valid)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    p = jnp.where(mask, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(ik == n_kv - 1)
    def _finish():
        l = l_ref[...]
        safe_l = jnp.where(l > 0.0, l, 1.0)
        o_ref[...] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "q_offset",
                     "block_q", "block_k", "interpret"))
def flash_attention_pallas(q, k, v, *, causal=True, window=None, softcap=None,
                           scale=None, q_offset=0, block_q=128, block_k=128,
                           interpret=True):
    """q: (B, Hq, Lq, D); k/v: (B, Hkv, Lk, D). Returns (B, Hq, Lq, D)."""
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)

    block_q = min(block_q, max(lq, 8))
    block_k = min(block_k, max(lk, 8))
    lq_pad = -(-lq // block_q) * block_q
    lk_pad = -(-lk // block_k) * block_k
    if lq_pad != lq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, lq_pad - lq), (0, 0)))
    if lk_pad != lk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, lk_pad - lk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, lk_pad - lk), (0, 0)))
    n_q = lq_pad // block_q
    n_kv = lk_pad // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, q_offset=q_offset, n_kv=n_kv, lq_valid=lq,
        lk_valid=lk)

    out = pl.pallas_call(
        kernel,
        grid=(b, hq, n_q, n_kv),
        in_specs=(
            pl.BlockSpec((None, None, block_q, d),
                         lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((None, None, block_k, d),
                         lambda b_, h, iq, ik: (b_, h // group, ik, 0)),
            pl.BlockSpec((None, None, block_k, d),
                         lambda b_, h, iq, ik: (b_, h // group, ik, 0)),
        ),
        out_specs=pl.BlockSpec((None, None, block_q, d),
                               lambda b_, h, iq, ik: (b_, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, lq_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :lq]
