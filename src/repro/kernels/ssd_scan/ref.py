"""Pure-jnp oracle for the Mamba-2 SSD scan (arXiv:2405.21060).

Sequential state-space recurrence, per head h in group g = h // (H/G):

  S_t = exp(dt[t,h] * A[h]) * S_{t-1} + dt[t,h] * B[t,g]^T x[t,h]
  y[t,h] = C[t,g] S_t + D[h] * x[t,h]

with S in R^{N x P} (state dim x head dim), A[h] < 0, dt > 0 (already
softplus-ed). Computed with an fp32 lax.scan over time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
            C: jax.Array, D: jax.Array | None = None,
            initial_state: jax.Array | None = None,
            return_state: bool = False):
    """x: (Bt,L,H,P); dt: (Bt,L,H); A: (H,); B/C: (Bt,L,G,N); D: (H,)."""
    bt, l, h, p = x.shape
    _, _, g, n = B.shape
    assert h % g == 0
    rep = h // g
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = jnp.repeat(B.astype(jnp.float32), rep, axis=2)  # (Bt,L,H,N)
    Cf = jnp.repeat(C.astype(jnp.float32), rep, axis=2)

    if initial_state is None:
        s0 = jnp.zeros((bt, h, n, p), jnp.float32)
    else:
        s0 = initial_state.astype(jnp.float32)

    def step(s, inputs):
        xt, dtt, bt_, ct = inputs  # (Bt,H,P), (Bt,H), (Bt,H,N), (Bt,H,N)
        decay = jnp.exp(dtt * Af)[..., None, None]          # (Bt,H,1,1)
        upd = (dtt[..., None] * bt_)[..., None] * xt[..., None, :]  # (Bt,H,N,P)
        s = decay * s + upd
        y = jnp.einsum("bhn,bhnp->bhp", ct, s)
        return s, y

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    s_fin, ys = jax.lax.scan(step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1)  # (Bt,L,H,P)
    if D is not None:
        y = y + D.astype(jnp.float32)[None, None, :, None] * xf
    y = y.astype(x.dtype)
    if return_state:
        return y, s_fin
    return y


def ssd_chunked_ref(x, dt, A, B, C, D=None, chunk=256,
                    initial_state=None, return_state=False):
    """Vectorized two-level SSD (the kernel's math, pure jnp, no
    sequential time scan — the model's default non-Pallas path).

    Intra-chunk runs the masked-decay attention-dual matmuls; the
    inter-chunk recurrence is closed-form as a (C x C) lower-triangular
    decay matrix over chunk states, so the whole computation is dense
    einsums — XLA-countable and TPU/SPMD friendly (an O(C^2/L) FLOP
    overhead buys the removal of an L-step dependency chain).
    """
    bt, l, h, p = x.shape
    _, _, g, n = B.shape
    rep = h // g
    chunk = min(chunk, l)
    assert l % chunk == 0
    nc = l // chunk
    xf = x.astype(jnp.float32).reshape(bt, nc, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(bt, nc, chunk, h)
    Af = A.astype(jnp.float32)
    Bf = jnp.repeat(B.astype(jnp.float32), rep, axis=2) \
        .reshape(bt, nc, chunk, h, n)
    Cf = jnp.repeat(C.astype(jnp.float32), rep, axis=2) \
        .reshape(bt, nc, chunk, h, n)

    lc = jnp.cumsum(dtf * Af, axis=2)                 # (bt,nc,Q,h)
    # ---- intra-chunk (masked decay kernel)
    seg = lc[:, :, :, None, :] - lc[:, :, None, :, :]  # (bt,nc,Q,Q,h)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    mask = tri[None, None, :, :, None]
    mdecay = jnp.where(mask, jnp.exp(seg), 0.0)
    cb = jnp.einsum("bcthn,bcshn->bctsh", Cf, Bf)
    w = cb * mdecay
    dtx = dtf[..., None] * xf
    y = jnp.einsum("bctsh,bcshp->bcthp", w, dtx)
    # ---- chunk states
    to_end = jnp.exp(lc[:, :, -1:, :] - lc)           # (bt,nc,Q,h)
    s_chunk = jnp.einsum("bcshn,bcshp->bchnp",
                         Bf * (to_end * dtf)[..., None], xf)
    # ---- inter-chunk: lower-tri decay matrix over chunks
    dtot = lc[:, :, -1, :]                            # (bt,nc,h) log decay
    cum = jnp.cumsum(dtot, axis=1)                    # inclusive
    # decay from end of chunk i to start of chunk j (i < j):
    # exp(sum_{m=i+1}^{j-1} dtot[m]) = exp(cum[j-1] - cum[i])
    cj = jnp.concatenate([jnp.zeros_like(cum[:, :1]), cum], axis=1)
    # decay(i->j) = exp(sum_{m=i+1}^{j-1} dtot[m]) = exp(cj[j] - cj[i+1])
    trij = jnp.tril(jnp.ones((nc, nc), bool), k=-1)
    expo = cj[:, :-1, None, :] - cj[:, None, 1:, :]
    tmat = jnp.where(trij[None, :, :, None], jnp.exp(expo), 0.0)
    s_before = jnp.einsum("bjih,bihnp->bjhnp", tmat, s_chunk)
    if initial_state is not None:
        s0 = initial_state.astype(jnp.float32)        # (bt,h,n,p)
        dec0 = jnp.exp(cj[:, :-1])                    # decay to chunk start
        s_before = s_before + dec0[..., None, None] * s0[:, None]
    y = y + jnp.exp(lc)[..., None] * jnp.einsum(
        "bcthn,bchnp->bcthp", Cf, s_before)
    y = y.reshape(bt, l, h, p)
    if D is not None:
        y = y + D.astype(jnp.float32)[None, None, :, None] \
            * x.astype(jnp.float32)
    y = y.astype(x.dtype)
    if return_state:
        s_fin = jnp.exp(cum[:, -1])[..., None, None] * (
            initial_state.astype(jnp.float32) if initial_state is not None
            else 0.0)
        s_fin = s_fin + jnp.einsum(
            "bih,bihnp->bhnp",
            jnp.exp(cum[:, -1:, :] - cum), s_chunk)
        return y, s_fin
    return y
