"""Pallas TPU kernel: Mamba-2 SSD chunked scan (arXiv:2405.21060 §6).

Two-level structure (deliberately the same shape as locality-aware list
ranking — DESIGN.md §3): the sequence is tiled into chunks of length Q;
each chunk is *contracted* locally with dense MXU matmuls (the analogue
of local contraction), the per-chunk states form a tiny sequential
recurrence across chunks (the base case), and the inter-chunk state is
*propagated* back into each position's output.

Per chunk (head h, group g, state dim N, head dim P):
  lc[t]   = cumsum_s<=t dt[s]*A                      (log decay prefix)
  y_intra = ((C B^T) ⊙ M) @ (dt ⊙ x),  M[t,s] = exp(lc[t]-lc[s])·[s<=t]
  y_inter = exp(lc[t]) * C[t] @ S_prev
  S_new   = exp(lc[Q-1]) * S_prev
            + sum_s exp(lc[Q-1]-lc[s]) dt[s] B[s]^T x[s]

TPU mapping: grid = (batch, heads, n_chunks), chunk dimension minor so
the (N, P) running state lives in VMEM scratch across sequential grid
steps. Blocks: (Q, P) x-tile, (Q, N) B/C tiles (GQA-style group fetch
folded into the index map), all VMEM-resident; the two (Q,Q) and (Q,N/P)
GEMMs hit the MXU. fp32 accumulation throughout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *,
                n_chunks: int, has_skip: bool, d_ref=None):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[...].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[...].astype(jnp.float32)      # (Q,)
    a = a_ref[0].astype(jnp.float32)          # scalar A[h]
    b = b_ref[...].astype(jnp.float32)        # (Q, N)
    c = c_ref[...].astype(jnp.float32)        # (Q, N)
    q = x.shape[0]

    lc = jnp.cumsum(dt * a)                   # (Q,) log-decay prefix
    # intra-chunk: masked decay kernel (the "attention duality" matmul)
    seg = lc[:, None] - lc[None, :]           # lc[t]-lc[s]
    tri = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    m = jnp.where(tri, jnp.exp(seg), 0.0)     # (Q, Q)
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    w = cb * m                                # (Q, Q)
    dtx = dt[:, None] * x                     # (Q, P)
    y = jax.lax.dot_general(w, dtx, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk: propagate carried state into this chunk's outputs
    s_prev = state_ref[...]                   # (N, P)
    y += jnp.exp(lc)[:, None] * jax.lax.dot_general(
        c, s_prev, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    # chunk state contraction + carry update
    decay_to_end = jnp.exp(lc[-1] - lc)       # (Q,)
    bw = b * (decay_to_end * dt)[:, None]     # (Q, N)
    s_chunk = jax.lax.dot_general(bw, x, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    state_ref[...] = jnp.exp(lc[-1]) * s_prev + s_chunk

    if has_skip:
        y += d_ref[0].astype(jnp.float32) * x
    y_ref[...] = y.astype(y_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x, dt, A, B, C, D=None, *, chunk=128, interpret=True):
    """x: (Bt,L,H,P); dt: (Bt,L,H); A,(D): (H,); B,C: (Bt,L,G,N)."""
    bt, l, h, p = x.shape
    _, _, g, n = B.shape
    assert h % g == 0
    rep = h // g
    chunk = min(chunk, l)
    assert l % chunk == 0, "sequence length must be divisible by chunk"
    n_chunks = l // chunk

    # layout: time-major per (batch, head) for clean chunk BlockSpecs
    xt = jnp.moveaxis(x, 2, 1)                       # (Bt,H,L,P)
    dtt = jnp.moveaxis(dt, 2, 1)                     # (Bt,H,L)
    bb = jnp.moveaxis(B, 2, 1)                       # (Bt,G,L,N)
    cc = jnp.moveaxis(C, 2, 1)                       # (Bt,G,L,N)

    has_skip = D is not None
    args = [xt, dtt, A, bb, cc]
    in_specs = [
        pl.BlockSpec((None, None, chunk, p), lambda b_, h_, ic: (b_, h_, ic, 0)),
        pl.BlockSpec((None, None, chunk), lambda b_, h_, ic: (b_, h_, ic)),
        pl.BlockSpec((1,), lambda b_, h_, ic: (h_,)),
        pl.BlockSpec((None, None, chunk, n),
                     lambda b_, h_, ic: (b_, h_ // rep, ic, 0)),
        pl.BlockSpec((None, None, chunk, n),
                     lambda b_, h_, ic: (b_, h_ // rep, ic, 0)),
    ]
    if has_skip:
        args.append(D)
        in_specs.append(pl.BlockSpec((1,), lambda b_, h_, ic: (h_,)))

    def kern(*refs):
        if has_skip:
            x_r, dt_r, a_r, b_r, c_r, d_r, y_r, s_r = refs
            _ssd_kernel(x_r, dt_r, a_r, b_r, c_r, y_r, s_r,
                        n_chunks=n_chunks, has_skip=True, d_ref=d_r)
        else:
            x_r, dt_r, a_r, b_r, c_r, y_r, s_r = refs
            _ssd_kernel(x_r, dt_r, a_r, b_r, c_r, y_r, s_r,
                        n_chunks=n_chunks, has_skip=False)

    yt = pl.pallas_call(
        kern,
        grid=(bt, h, n_chunks),
        in_specs=tuple(in_specs),
        out_specs=pl.BlockSpec((None, None, chunk, p),
                               lambda b_, h_, ic: (b_, h_, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((bt, h, l, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*args)
    return jnp.moveaxis(yt, 1, 2)  # (Bt,L,H,P)
