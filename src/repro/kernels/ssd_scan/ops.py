"""Public SSD op: Pallas chunked scan with reference fallback and a
recompute-based custom vjp (training-usable)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssd_scan import kernel as _kernel
from repro.kernels.ssd_scan import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def ssd_scan(x, dt, A, B, C, D=None, chunk=128, use_pallas=True):
    """Mamba-2 SSD: returns y of shape (Bt, L, H, P)."""
    l = x.shape[1]
    if use_pallas and l % min(chunk, l) == 0:
        return _kernel.ssd_scan_pallas(x, dt, A, B, C, D, chunk=chunk,
                                       interpret=not _on_tpu())
    return _ref.ssd_ref(x, dt, A, B, C, D)


def _fwd(x, dt, A, B, C, D, chunk, use_pallas):
    return ssd_scan(x, dt, A, B, C, D, chunk, use_pallas), (x, dt, A, B, C, D)


def _bwd(chunk, use_pallas, res, g):
    x, dt, A, B, C, D = res
    if D is None:
        _, vjp = jax.vjp(lambda x, dt, A, B, C:
                         _ref.ssd_ref(x, dt, A, B, C, None), x, dt, A, B, C)
        return vjp(g) + (None,)
    _, vjp = jax.vjp(lambda x, dt, A, B, C, D:
                     _ref.ssd_ref(x, dt, A, B, C, D), x, dt, A, B, C, D)
    return vjp(g)


ssd_scan.defvjp(_fwd, _bwd)


def ssd_decode_step(x, dt, A, B, C, D, state):
    """Single-token decode: update the (Bt,H,N,P) state and emit y.

    x: (Bt,H,P); dt: (Bt,H); B,C: (Bt,G,N). Returns (y, new_state)."""
    import jax.numpy as jnp
    bt, h, p = x.shape
    g = B.shape[1]
    rep = h // g
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = jnp.repeat(B.astype(jnp.float32), rep, axis=1)
    Cf = jnp.repeat(C.astype(jnp.float32), rep, axis=1)
    decay = jnp.exp(dtf * A.astype(jnp.float32))[..., None, None]
    upd = (dtf[..., None] * Bf)[..., None] * xf[..., None, :]
    state = decay * state.astype(jnp.float32) + upd
    y = jnp.einsum("bhn,bhnp->bhp", Cf, state)
    if D is not None:
        y = y + D.astype(jnp.float32)[None, :, None] * xf
    return y.astype(x.dtype), state
