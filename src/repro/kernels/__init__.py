"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel package ships three modules:
  kernel.py — the pl.pallas_call with explicit BlockSpec VMEM tiling
              (TPU target, validated in interpret mode on CPU),
  ops.py    — the jit'd public wrapper (dispatch, batching, fallbacks),
  ref.py    — the pure-jnp oracle used by the tests.

Kernels:
  local_chase     — in-VMEM vectorized pointer doubling: the paper's
                    local-contraction hot loop (§2.3) adapted to the VPU.
  flash_attention — blockwise causal/sliding-window GQA attention with
                    logit soft-capping (Gemma-2) — the LM substrate's
                    dominant non-GEMM kernel.
  ssd_scan        — Mamba-2 SSD chunked scan; structurally the same
                    contract→base→propagate pattern as locality-aware
                    list ranking (DESIGN.md §3).
"""
