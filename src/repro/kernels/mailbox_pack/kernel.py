"""Pallas TPU kernel: fused wire-pack + mailbox bucket-scatter.

The packed exchange path (see ``repro.core.listrank.exchange``) needs,
per hop, the column-major ``(W, n_buckets*cap)`` int32 send buffer

    out[w, slots[i]] = cols[w][i]   for every shipping message i

where ``cols`` are the W bit-cast wire word-planes of the payload and
``slots`` the input-aligned mailbox slot (out-of-range => the message
does not ship this hop). XLA runs one scatter per word-plane, touching
the slot indices W times; this kernel walks the messages once and
writes each message's W words together, straight from the (unsorted,
per-leaf) planes resident in VMEM.

Grid: a single program owning the whole buffers in VMEM — Q and the
mailbox buffer are queue-sized; the VMEM budget is enforced by
``ops.py``, which falls back to the XLA path otherwise. Interpret mode
on CPU (this container), compiled on a real TPU — mirroring
``repro.kernels.local_chase``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pack_kernel(*refs, n_cols: int, n_rows: int):
    col_refs = refs[:n_cols]
    slots_ref, out_ref = refs[n_cols:]
    out_ref[...] = jnp.zeros_like(out_ref)
    q = slots_ref.shape[0]

    def body(i, carry):
        f = slots_ref[i]

        @pl.when(f < n_rows)
        def _():
            for w in range(n_cols):
                out_ref[w, pl.ds(f, 1)] = col_refs[w][pl.ds(i, 1)]

        return carry

    jax.lax.fori_loop(0, q, body, 0)


@functools.partial(jax.jit, static_argnames=("n_rows", "interpret"))
def mailbox_pack_pallas(cols, slots: jax.Array, n_rows: int,
                        interpret: bool = True) -> jax.Array:
    """(Q,)*W word-planes + slot indices -> (W, n_rows) send buffer."""
    n_cols = len(cols)
    kernel = functools.partial(_pack_kernel, n_cols=n_cols, n_rows=n_rows)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n_cols, n_rows), jnp.int32),
        interpret=interpret,
    )(*cols, slots)
