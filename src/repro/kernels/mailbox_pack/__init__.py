"""Fused wire-packing + mailbox bucket-scatter kernel (exchange layer)."""
from repro.kernels.mailbox_pack.ops import mailbox_pack  # noqa: F401
