"""Public wrapper for mailbox_pack: dispatch between the fused Pallas
kernel and the XLA fallback, with the interpret-mode switch for CPU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.mailbox_pack import kernel as _kernel
from repro.kernels.mailbox_pack import ref as _ref

#: per-core VMEM budget for the resident working set (planes + buffer).
VMEM_BUDGET = 12 * 1024 * 1024


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def mailbox_pack(cols, slots: jax.Array, n_rows: int,
                 use_pallas: bool = False) -> jax.Array:
    """Build the packed (W, n_rows) int32 mailbox send buffer.

    out[w, slots[i]] = cols[w][i] for messages with slots[i] < n_rows;
    everything else is zero (invalid on the wire). ``use_pallas`` routes
    through the fused VMEM kernel when the working set fits.
    """
    q = slots.shape[0]
    w = len(cols)
    working_set = 4 * (q * (w + 1) + n_rows * w)
    if use_pallas and working_set <= VMEM_BUDGET:
        return _kernel.mailbox_pack_pallas(tuple(cols), slots, n_rows,
                                           interpret=not _on_tpu())
    return _ref.mailbox_pack_ref(cols, slots, n_rows)
