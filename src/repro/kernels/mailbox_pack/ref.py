"""XLA reference path for mailbox packing: one scatter per wire
word-plane into the column-major send buffer. Identical results to the
Pallas kernel (pure data movement)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mailbox_pack_ref(cols, slots: jax.Array, n_rows: int) -> jax.Array:
    """See :func:`repro.kernels.mailbox_pack.ops.mailbox_pack`."""
    planes = [jnp.zeros(n_rows, jnp.int32).at[slots].set(c, mode="drop")
              for c in cols]
    return jnp.stack(planes)
