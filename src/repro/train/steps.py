"""Training / serving step functions (the units the dry-run lowers).

``train_step`` is the full production step: forward (remat'd scanned
layers), next-token cross-entropy with z-loss and MoE aux loss,
backward, grad clip, AdamW (optionally ZeRO-sharded / int8 states).
Gradient accumulation over microbatches happens via an inner scan.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.optim import adamw, schedule as sched


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: adamw.AdamWConfig = adamw.AdamWConfig()
    schedule: str = "cosine"
    warmup_steps: int = 100
    total_steps: int = 10_000
    z_loss: float = 1e-4
    microbatches: int = 1  # gradient accumulation factor


def next_token_loss(logits, labels, cfg: M.ModelConfig, z_weight=1e-4):
    """Shifted cross-entropy. labels: (B, L_total) aligned with logits;
    positions with label < 0 are masked (prefix/padding)."""
    logits = logits[:, :-1]
    targets = labels[:, 1:]
    mask = targets >= 0
    tclip = jnp.clip(targets, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, tclip[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * mask
    z = jnp.square(lse) * mask
    denom = jnp.maximum(mask.sum(), 1)
    return nll.sum() / denom + z_weight * z.sum() / denom


def loss_fn(params, batch, cfg: M.ModelConfig, tcfg: TrainConfig):
    logits, aux = M.forward(params, batch, cfg)
    labels = batch["labels"]
    loss = next_token_loss(logits, labels, cfg, tcfg.z_loss)
    if cfg.moe:
        loss = loss + cfg.aux_loss_weight * aux
    return loss, {"aux_loss": aux}


def _split_microbatches(batch, n):
    return jax.tree.map(lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]),
                        batch)


def train_step(params, opt_state, batch, cfg: M.ModelConfig,
               tcfg: TrainConfig):
    """One optimizer step (with grad accumulation when microbatches>1)."""

    if tcfg.microbatches > 1:
        micro = _split_microbatches(batch, tcfg.microbatches)

        def acc(carry, mb):
            g_acc, l_acc = carry
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb, cfg, tcfg)
            return (jax.tree.map(jnp.add, g_acc, grads), l_acc + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss), _ = jax.lax.scan(acc, (zeros, jnp.float32(0)), micro)
        grads = jax.tree.map(lambda g: g / tcfg.microbatches, grads)
        loss = loss / tcfg.microbatches
        extras = {}
    else:
        (loss, extras), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch, cfg, tcfg)

    lr_scale = {
        "cosine": sched.cosine_warmup,
        "rsqrt": sched.rsqrt,
        "constant": sched.constant,
    }[tcfg.schedule](opt_state["step"] + 1,  # step counter is 0-based
                     warmup_steps=tcfg.warmup_steps,
                     total_steps=tcfg.total_steps)
    params, opt_state, om = adamw.update(grads, opt_state, params,
                                         tcfg.optimizer, lr_scale)
    metrics = {"loss": loss, **om, **extras}
    return params, opt_state, metrics


def eval_step(params, batch, cfg: M.ModelConfig, tcfg: TrainConfig):
    loss, extras = loss_fn(params, batch, cfg, tcfg)
    return {"loss": loss, **extras}
