from repro.train import steps

__all__ = ["steps"]
