"""Batched multi-instance front door (the serving-side scenario).

Many small list-ranking or tree queries must not each pay a solver
invocation (compile-cache lookup, host round trips, p collective
startups): :func:`rank_lists` packs B independent instances into ONE
block-sharded instance — ids offset-relabelled per instance, the tail
padded with weight-0 singletons (``instances.pad_to_multiple``) — and
runs a single jitted mesh solve. Lists never cross instance boundaries
(every id is relabelled into its own offset window), so the per-round
collective count of the packed solve is *identical* to a
single-instance solve of the same total size: batching costs volume,
never startups. ``tests/test_treealg.py`` pins that claim with jaxpr
collective counts.

:func:`solve_forest` is the tree-level door: B independent trees pack
into one forest (euler.py handles multi-root inputs natively), one
device tour build + one batched solve yields every tree's
:class:`~repro.core.treealg.ops.TreeStats`.
"""
from __future__ import annotations

from typing import Sequence

import jax
import numpy as np

from repro.core.listrank import instances
from repro.core.listrank.api import rank_list_with_stats
from repro.core.listrank.config import ListRankConfig

#: largest packed id the offset relabeling may produce. Ids ride the
#: int32 wire format, and the driver pads the packed instance up to a
#: PE multiple *after* packing, so leave 2^16 headroom below 2^31-1
#: instead of wrapping silently at the ``astype(np.int32)``.
PACKED_ID_LIMIT = 2**31 - 2**16


def _check_packed_size(total: int, what: str, limit: int = PACKED_ID_LIMIT):
    """Host-side int32-overflow guard for offset relabeling: ``total``
    is the largest id the packed instance can produce (before PE
    padding). Runs on shapes only — callers invoke it before touching
    any element data."""
    if total > limit:
        raise ValueError(
            f"{what}: packed instance needs ids up to {total}, which "
            f"overflows the int32 wire format (limit {limit} with "
            f"PE-padding headroom); split the batch")


def pack_instances(batch: Sequence[tuple[np.ndarray, np.ndarray]]):
    """Offset-relabel and concatenate B (succ, rank) instances.

    Returns (succ, rank, offsets): instance b occupies the id window
    ``[offsets[b], offsets[b+1])``. Weight dtypes are promoted to their
    common numpy result type (int stays int32 on the wire, float
    float32 — see ``api.chase_leaves``).
    """
    if not batch:
        raise ValueError("empty instance batch")
    sizes = np.array([np.asarray(s).shape[0] for s, _ in batch], np.int64)
    # shape-only overflow check BEFORE any elementwise validation: the
    # relabeled ids must fit the int32 wire format
    _check_packed_size(int(sizes.sum()), "pack_instances")
    for b, (s, r) in enumerate(batch):
        s = np.asarray(s)
        if np.asarray(r).shape != s.shape:
            raise ValueError("succ/rank shape mismatch in batch")
        # ids must stay inside the instance: an out-of-range id would
        # silently alias into a neighbor's offset window after packing
        if s.size and not ((s >= 0) & (s < s.shape[0])).all():
            raise ValueError(f"instance {b}: succ ids out of range")
    offsets = np.concatenate([[0], np.cumsum(sizes)])
    succ = np.concatenate(
        [np.asarray(s, np.int64) + off
         for (s, _), off in zip(batch, offsets)]) if sizes.sum() else \
        np.zeros(0, np.int64)
    wdt = np.result_type(*[np.asarray(r).dtype for _, r in batch])
    rank = np.concatenate(
        [np.asarray(r).astype(wdt) for _, r in batch]) if sizes.sum() else \
        np.zeros(0, wdt)
    return succ.astype(np.int32), rank, offsets


def unpack_results(succ: np.ndarray, rank: np.ndarray,
                   offsets: np.ndarray):
    """Inverse of :func:`pack_instances` on solver output (padding
    beyond ``offsets[-1]`` is dropped, ids shift back per window)."""
    out = []
    for b in range(offsets.shape[0] - 1):
        lo, hi = int(offsets[b]), int(offsets[b + 1])
        out.append((succ[lo:hi] - lo, rank[lo:hi]))
    return out


def rank_lists_with_stats(batch, mesh, pe_axes=None,
                          cfg: ListRankConfig | None = None, **kw):
    """Rank B independent instances in ONE jitted mesh solve.

    Args:
      batch: sequence of (succ, rank) pairs (numpy or jax arrays),
        each a self-contained instance with terminals pointing to
        themselves.

    Returns:
      (results, stats): ``results[b]`` is instance b's (succ, rank) in
      its own id space; ``stats`` the single solve's counters.
    """
    batch = [(np.asarray(jax.device_get(s)), np.asarray(jax.device_get(r)))
             for s, r in batch]
    succ, rank, offsets = pack_instances(batch)
    p = 1
    axes = tuple(pe_axes) if pe_axes is not None else tuple(mesh.axis_names)
    for a in axes:
        p *= mesh.shape[a]
    succ, rank = instances.pad_to_multiple(succ, rank, p)
    s_out, r_out, stats = rank_list_with_stats(succ, rank, mesh,
                                               pe_axes=pe_axes, cfg=cfg, **kw)
    s_np = np.asarray(jax.device_get(s_out))
    r_np = np.asarray(jax.device_get(r_out))
    return unpack_results(s_np, r_np, offsets), stats


def rank_lists(batch, mesh, **kw):
    """Convenience wrapper: the per-instance (succ, rank) results only."""
    results, _ = rank_lists_with_stats(batch, mesh, **kw)
    return results


def solve_forest(parents: Sequence[np.ndarray], mesh, pe_axes=None,
                 cfg: ListRankConfig | None = None, **kw):
    """Tree statistics for B independent trees in one batched solve.

    Packs the parent arrays into one forest (offset-relabelled roots
    stay self-parented), builds a single device tour, ranks both
    weightings through the batched front door, and splits the
    :class:`~repro.core.treealg.ops.TreeStats` back per tree.
    """
    from repro.core.treealg import ops
    if not parents:
        raise ValueError("empty forest batch")
    # shape-only overflow guard BEFORE any conversion touches element
    # data: arc ids of the packed forest's tour reach 2 * n_packed
    _check_packed_size(
        2 * sum(q.shape[0] if hasattr(q, "shape") else len(q)
                for q in parents), "solve_forest")
    parents = [np.asarray(jax.device_get(q)).astype(np.int64)
               for q in parents]
    for b, q in enumerate(parents):
        # validate per tree BEFORE packing: an out-of-range parent
        # would become a valid pointer into a neighbor's id window
        if q.shape[0] == 0 or not ((q >= 0) & (q < q.shape[0])).all():
            raise ValueError(f"tree {b}: parent pointers out of range")
    sizes = np.array([q.shape[0] for q in parents], np.int64)
    offsets = np.concatenate([[0], np.cumsum(sizes)])
    packed = np.concatenate(
        [q + off for q, off in zip(parents, offsets)])
    st = ops.tree_stats(packed, mesh, pe_axes=pe_axes, cfg=cfg, **kw)
    out = []
    for b in range(len(parents)):
        lo, hi = int(offsets[b]), int(offsets[b + 1])
        out.append(ops.TreeStats(
            parent=st.parent[lo:hi] - lo, root_of=st.root_of[lo:hi] - lo,
            depth=st.depth[lo:hi], subtree_size=st.subtree_size[lo:hi],
            preorder=st.preorder[lo:hi], postorder=st.postorder[lo:hi],
            stats=st.stats))
    return out
