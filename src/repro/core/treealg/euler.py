"""Device-side Euler-tour construction from a sharded parent array.

The tree (or forest) arrives as block-sharded parent pointers — node c
on PE ``c // m`` with ``parent[root] == root`` — and leaves as the
tour's list-ranking instance: a sharded successor array over the arc
ids plus the matching weights. The layout gives node c the two arc
slots ``down(c) = 2c`` and ``up(c) = 2c + 1`` (``(q→c)`` and ``(c→q)``
for q = parent[c]); a root's slots are weight-0 self-loop dummies, so
the arc array is exactly twice the node array and shards on the same
block boundaries — PE k owns the arcs of its own nodes.

Construction is two exchange rounds over the mesh (paper §2.4 routing,
one packed ``all_to_all`` each on the direct plan):

  1. every non-root node reports ``(child, parent)`` to its parent's
     owner. The owner recovers each node's adjacency list as one run of
     :func:`exchange.sort_and_group` (children pre-sorted by id, then
     stably grouped by parent — the same single-sort discipline as the
     routing hot path), which yields first-child marks (run starts) and
     next-sibling links (run neighbors) in one pass.
  2. the owner replies ``(next_sibling, parent_is_root, parent's first
     child)`` to each child's owner.

Everything else is local arc arithmetic (module constants of the
layout). Capacities for both rounds are *exact*: the host derives the
per-(sender, receiver) message histogram from the parent array, so no
leftover re-routing round is ever needed — any nonzero
``tour_undelivered`` stat is defensive and triggers the standard
doubling retry.

The host-side :func:`repro.core.listrank.instances.gen_euler_tour` is
the oracle (its ``2(c-1)`` arc ids shift to this module's ``2c`` by
dropping the root's two dummy slots — see :func:`oracle_tour`).
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.listrank import exchange as exchange_lib
from repro.core.listrank import transport as transport_lib
from repro.obs import telemetry as tele_lib
from repro.obs import trace as trace_lib
from repro.core.listrank.config import ListRankConfig
from repro.core.listrank.exchange import INT_MAX, MeshPlan


def down(c):
    """Arc id of (parent(c) → c) in the device layout."""
    return 2 * c


def up(c):
    """Arc id of (c → parent(c)) in the device layout."""
    return 2 * c + 1


def tour_caps(parent: np.ndarray, p: int) -> tuple[int, int]:
    """Exact per-peer mailbox capacities for the two construction
    rounds: the max entry of the (sender, receiver) message histogram,
    and of its transpose for the replies."""
    n = parent.shape[0]
    m = n // p
    idx = np.arange(n)
    nonroot = parent != idx
    hist = np.zeros((p, p), np.int64)
    np.add.at(hist, (idx[nonroot] // m, parent[nonroot] // m), 1)
    c1 = int(hist.max()) if nonroot.any() else 0
    return max(c1, 8), max(c1, 8)  # reply histogram = transpose, same max


def _build_sharded(parent, cut, *, plan: MeshPlan, m: int, child_cap: int,
                   reply_cap: int, weighted: bool, closed: bool):
    """Per-PE tour construction (runs under shard_map)."""
    pe = plan.my_id().astype(jnp.int32)
    base = pe * m
    lidx = jnp.arange(m, dtype=jnp.int32)
    gid = base + lidx
    q = parent.astype(jnp.int32)
    is_root = q == gid
    nonroot = ~is_root

    def owner_of(g):
        return g // m

    def reply_fn(delivered, dval):
        # adjacency runs: one pre-sort by child id, then the shared
        # sort_and_group stably groups by parent — within each parent's
        # run the children are ascending, i.e. the tour's adjacency
        # order.
        ch, par = delivered["child"], delivered["parent"]
        ordc = jnp.argsort(jnp.where(dval, ch, INT_MAX), stable=True)
        ch_c, par_c, val_c = ch[ordc], par[ordc], dval[ordc]
        order, skey, _, newrun = exchange_lib.sort_and_group(par_c, val_c,
                                                            INT_MAX)
        ch_s = ch_c[order]
        val_s = skey != INT_MAX

        # first child of each local node: the run starts, scattered by
        # the (local) parent id. skey of a valid run is owned here by
        # routing.
        pslot = jnp.where(val_s, skey - base, m)
        fc = jnp.full(m, -1, jnp.int32).at[
            jnp.where(newrun & val_s, pslot, m)].set(ch_s, mode="drop")
        # next sibling: the following sorted row, if in the same run
        has_next = jnp.concatenate([~newrun[1:], jnp.zeros((1,), jnp.bool_)])
        ns_row = jnp.where(
            has_next,
            jnp.concatenate([ch_s[1:], jnp.full((1,), -1, jnp.int32)]), -1)
        pslot_c = jnp.clip(pslot, 0, m - 1)
        par_root = val_s & is_root[pslot_c]
        par_fc = fc[pslot_c]
        # reply (next sibling, parent-is-root, parent's first child) to
        # each child's owner
        return ({"child": ch_s, "ns": ns_row, "proot": par_root,
                 "pfc": par_fc}, owner_of(ch_s), val_s, fc)

    # children report to their parent's owner; the owner groups them
    # into adjacency runs and replies (exchange.request_reply).
    rdel, rval, fc, rr_st = exchange_lib.request_reply(
        plan, child_cap, reply_cap, {"child": gid, "parent": q},
        owner_of(q).astype(jnp.int32), nonroot, reply_fn)
    rslot = jnp.where(rval, rdel["child"] - base, m)
    ns = jnp.full(m, -1, jnp.int32).at[rslot].set(rdel["ns"], mode="drop")
    proot = jnp.zeros(m, jnp.bool_).at[rslot].set(rdel["proot"], mode="drop")
    pfc = jnp.full(m, -1, jnp.int32).at[rslot].set(rdel["pfc"], mode="drop")
    have = jnp.zeros(m, jnp.bool_).at[rslot].set(True, mode="drop")

    # local arc assembly (tour successor rules, euler.py module doc)
    succ_down = jnp.where(fc >= 0, down(fc), up(gid))
    # last sibling: up(parent), except at the root where the tour is cut
    # (terminal) — or, for a closed tour, wraps to the root's first arc.
    at_root_end = down(pfc) if closed else up(gid)
    succ_up = jnp.where(ns >= 0, down(ns),
                        jnp.where(proot, at_root_end, up(q)))
    if closed:
        # cut the circular tour at `cut`: down(cut) becomes the terminal
        succ_down = jnp.where(gid == cut, down(gid), succ_down)
    succ_down = jnp.where(nonroot, succ_down, down(gid))
    succ_up = jnp.where(nonroot, succ_up, up(gid))
    succ = jnp.stack([succ_down, succ_up], axis=1).reshape(2 * m)

    arc_gid = 2 * base + jnp.arange(2 * m, dtype=jnp.int32)
    is_term = succ == arc_gid
    if weighted:
        w = jnp.where(arc_gid % 2 == 0, jnp.int32(1), jnp.int32(-1))
    else:
        w = jnp.ones(2 * m, jnp.int32)
    w = jnp.where(is_term, 0, w)

    missing = jnp.sum(nonroot & ~have).astype(jnp.int32)
    stats = {"tour_undelivered": plan.psum(missing + rr_st["leftover"]),
             "tour_msgs": plan.psum(rr_st["sent"])}
    if plan.telemetry:
        # per-PE tour-round telemetry (graph family), as a 4th sharded
        # output — never psum'd (zero added collectives).
        tele = tele_lib.merge(tele_lib.stage_zero(plan.indirection.depth),
                              {"graph": rr_st["telemetry"]})
        return succ, w, stats, jax.tree.map(lambda v: v[None], tele)
    return succ, w, stats


@functools.lru_cache(maxsize=128)
def _jitted_builder(mesh, plan, m, child_cap, reply_cap, weighted, closed):
    fn = functools.partial(_build_sharded, plan=plan, m=m,
                           child_cap=child_cap, reply_cap=reply_cap,
                           weighted=weighted, closed=closed)
    spec = P(plan.pe_axes)
    out_specs = ((spec, spec, P(), spec) if plan.telemetry
                 else (spec, spec, P()))
    return transport_lib.device_run(mesh, plan.pe_axes, fn,
                                    in_specs=(spec, P()),
                                    out_specs=out_specs)


def build_tour(parent, mesh, pe_axes=None, cfg: ListRankConfig | None = None,
               weighted: bool = False, cut_at: int | None = None,
               max_retries: int = 2, tracer=None):
    """Build the Euler tour of a block-sharded tree/forest on the mesh.

    Args:
      parent: (n_nodes,) parent pointers, ``parent[root] == root``.
        Multiple roots = a forest (each tree's tour is cut at its root).
        Padded host-side with singleton roots to a PE multiple.
      weighted: ±1 depth weights instead of unit weights.
      cut_at: close every root loop and cut the (single) tree's circular
        tour at ``down(cut_at)`` instead — the re-rooting primitive
        behind :func:`repro.core.treealg.ops.root_tree`. Requires a
        single-tree input.

    Returns:
      (succ, weight, n_pad): sharded (2*n_pad,) int32 arrays — a
      list-ranking instance over the arc ids — and the padded node
      count. Slots of padding/root nodes are weight-0 self-loops.
    """
    cfg = cfg or ListRankConfig()
    pe_axes = tuple(pe_axes) if pe_axes is not None else tuple(mesh.axis_names)
    backend, mesh = transport_lib.resolve_backend(cfg.backend, mesh, pe_axes)
    if backend == "simshard":
        transport_lib.check_sim_config(cfg)
    parent_np = np.asarray(jax.device_get(parent)).astype(np.int64)
    n = parent_np.shape[0]
    if n == 0:
        raise ValueError("empty tree")
    idx = np.arange(n)
    if not ((parent_np >= 0) & (parent_np < n)).all():
        raise ValueError("parent pointers out of range")
    closed = cut_at is not None
    if closed:
        roots = idx[parent_np == idx]
        if roots.size != 1:
            raise ValueError("cut_at requires a single-tree input")
        if not 0 <= cut_at < n:
            raise ValueError("cut_at out of range")
        if cut_at == int(roots[0]):
            closed = False  # already rooted there; the default cut is it
    plan = MeshPlan.from_mesh(mesh, pe_axes, None,
                              wire_packing=cfg.wire_packing,
                              pallas_pack=cfg.use_pallas_pack,
                              telemetry=cfg.telemetry)
    p = plan.p
    pad = (-n) % p
    parent_pad = np.concatenate([parent_np, np.arange(n, n + pad)])
    n_pad = n + pad
    m = n_pad // p
    parent_d = transport_lib.put_sharded(mesh, pe_axes,
                                         jnp.asarray(parent_pad, jnp.int32))
    cut_d = jnp.int32(cut_at if closed else -1)

    cap1, cap2 = tour_caps(parent_pad, p)
    tr = trace_lib.ensure(tracer)
    with tr.span("build_tour", cat="solve", n_nodes=n, p=p,
                 backend=transport_lib.backend_name(mesh)) as tour_span:
        for attempt in range(max_retries + 1):
            builder = _jitted_builder(mesh, plan, m, cap1, cap2, weighted,
                                      closed)
            att = tr.begin(f"build_tour#{attempt + 1}", cat="stage-attempt",
                           stage="build_tour", level=-1,
                           attempt=attempt + 1)
            t0 = time.time()
            out = builder(parent_d, cut_d)
            succ, w, stats = out[0], out[1], out[2]
            jax.block_until_ready((succ, w))
            dt = time.time() - t0
            if int(jax.device_get(stats["tour_undelivered"])) == 0:
                util = {}
                if plan.telemetry:
                    agg = tele_lib.aggregate(jax.device_get(out[3]))
                    util = tele_lib.utilization(agg)
                    tour_span.annotate(
                        telemetry=tele_lib.StageRecord(
                            label="build_tour", kind="tour", level=-1,
                            caps={"graph": (cap1, cap2)}, queue_cap=0,
                            tele=agg).to_json())
                tr.end(att, wall_s=dt, outcome="committed", **util)
                tour_span.annotate(attempts=attempt + 1, outcome="ok")
                return succ, w, n_pad
            tr.end(att, wall_s=dt, outcome="overflow")
            cap1, cap2 = 2 * cap1, 2 * cap2  # defensive: caps are exact
        tour_span.annotate(outcome="exhausted")
    raise RuntimeError(
        f"Euler tour construction incomplete after {max_retries + 1} "
        f"attempts; stats={jax.device_get(stats)}")


def oracle_tour(n_nodes: int, parent: np.ndarray) -> np.ndarray:
    """Host-side oracle in the *device* layout: the expected successor
    array for a rooted forest, built by relabeling the
    ``instances.gen_euler_tour`` construction rules (its ``2(c-1)``
    ids become ``2c``; roots gain self-loop dummy slots)."""
    from repro.core.listrank import instances
    n = n_nodes
    idx = np.arange(n)
    is_root = parent == idx
    cand = idx[~is_root]
    first_child, next_sib = instances.adjacency_links(np.asarray(parent,
                                                                 np.int64))
    succ = np.arange(2 * n, dtype=np.int64)
    c = cand
    q = parent[c]
    fc = first_child[c]
    ns = next_sib[c]
    succ[2 * c] = np.where(fc >= 0, 2 * fc, 2 * c + 1)
    succ[2 * c + 1] = np.where(ns >= 0, 2 * ns,
                               np.where(is_root[q], 2 * c + 1, 2 * q + 1))
    return succ
