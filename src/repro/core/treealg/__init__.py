"""Euler-tour tree algorithms on top of distributed list ranking.

The paper motivates list ranking by its "many applications as a
subroutine" — above all Euler-tour tree computations. This package is
that application layer:

- :mod:`~repro.core.treealg.euler` — device-side tour construction
  from a sharded parent array (two packed exchange rounds),
- :mod:`~repro.core.treealg.ops` — ``root_tree``, ``node_depth``,
  ``subtree_size``, ``preorder``/``postorder`` via closed-form
  arc-position arithmetic over ranked tours (DESIGN.md §8),
- :mod:`~repro.core.treealg.batch` — the batched multi-instance front
  door (``rank_lists`` / ``solve_forest``): B independent instances,
  one jitted mesh solve.
"""
from repro.core.treealg.euler import build_tour, oracle_tour, tour_caps
from repro.core.treealg.ops import (TreeStats, is_ancestor, node_depth,
                                    postorder, preorder, root_tree,
                                    roots_and_sizes, subtree_interval,
                                    subtree_size, tree_stats)
from repro.core.treealg.batch import (pack_instances, rank_lists,
                                      rank_lists_with_stats, solve_forest,
                                      unpack_results)

__all__ = [
    "build_tour", "oracle_tour", "tour_caps",
    "TreeStats", "is_ancestor", "node_depth", "postorder", "preorder",
    "root_tree", "roots_and_sizes", "subtree_interval", "subtree_size",
    "tree_stats",
    "pack_instances", "rank_lists", "rank_lists_with_stats",
    "solve_forest", "unpack_results",
]
