"""Tree statistics from ranked Euler tours (treealg operations layer).

Every operation reduces to list ranking of the device-built tour
(:mod:`repro.core.treealg.euler`) plus closed-form arc-position
arithmetic (DESIGN.md §8). With the tour cut at each root, the solver's
sink-ranking gives, per arc ``a``, the weighted distance ``rank(a)``
from ``a`` to its tree's terminal; writing ``pos`` for the position
from the tour start and ``L = 2(size-1)`` for the tree's arc count:

  - unit weights:  ``pos(a) = L - 1 - rank1(a)``
  - ±1 weights:    ``depth(c) = 2 - rank±(down(c))``   (the +1 corrects
    the terminal arc's zeroed weight; see gen_euler_tour)
  - ``subtree_size(c) = (rank1(down(c)) - rank1(up(c)) + 1) // 2`` —
    position-difference only, so no per-tree constants needed
  - ``preorder(c)  = (pos(down(c)) + 1 + depth(c)) // 2``
  - ``postorder(c) = (pos(up(c)) + 2 - depth(c)) // 2 - 1``

``preorder``/``postorder`` are 0-based per tree (roots at 0 and
size-1), with children visited in ascending-id order — the tour's
adjacency order. ``tree_stats`` needs both weightings and gets them
from ONE mesh solve by batching the two instances through
:func:`repro.core.treealg.batch.rank_lists_with_stats`; ``node_depth``
and ``subtree_size`` are single-solve fast paths.

``root_tree`` is the edge-orientation application: build the tree's
*circular* tour, cut it at the new root (``euler.build_tour(cut_at=)``),
rank, and orient every edge toward the smaller tour position.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core.listrank.api import rank_list_with_stats
from repro.core.listrank.config import ListRankConfig
from repro.core.treealg import euler


@dataclasses.dataclass(frozen=True)
class TreeStats:
    """Per-node statistics of a rooted tree or forest."""
    parent: np.ndarray        #: the input rooting
    root_of: np.ndarray       #: each node's tree root
    depth: np.ndarray         #: depth[root] == 0
    subtree_size: np.ndarray  #: subtree_size[root] == tree size
    preorder: np.ndarray      #: 0-based per tree, ascending-id children
    postorder: np.ndarray     #: 0-based per tree; root == size - 1
    stats: dict               #: solver stats of the underlying solve(s)

    @property
    def n_nodes(self) -> int:
        return self.parent.shape[0]

    def is_ancestor(self, u, v) -> np.ndarray:
        """True iff ``u`` is an (inclusive) ancestor of ``v`` — the
        closed-form pre/postorder interval test, no solves."""
        return is_ancestor(self.preorder, self.postorder, self.root_of,
                           u, v)

    def subtree_interval(self, u):
        """Preorder interval [lo, hi] covered by ``u``'s subtree."""
        return subtree_interval(self.preorder, self.subtree_size, u)


def is_ancestor(preorder, postorder, root_of, u, v) -> np.ndarray:
    """Closed-form ancestor test from pre/postorder numbers.

    ``u`` is an ancestor of ``v`` (every node is its own ancestor) iff
    they share a tree and ``v``'s DFS visit nests inside ``u``'s:
    ``pre[u] <= pre[v]`` and ``post[v] <= post[u]``. Pre/postorder are
    0-based *per tree*, so the same-tree check (``root_of`` — or a
    component labeling) is part of the test. Vectorizes over ``u``/``v``
    (numpy broadcasting); used by both :meth:`TreeStats.is_ancestor`
    and the graphalg query layer. No communication — pure arithmetic.
    """
    u = np.asarray(u)
    v = np.asarray(v)
    preorder = np.asarray(preorder)
    postorder = np.asarray(postorder)
    root_of = np.asarray(root_of)
    return (root_of[u] == root_of[v]) & (preorder[u] <= preorder[v]) \
        & (postorder[v] <= postorder[u])


def subtree_interval(preorder, subtree_size, u):
    """The preorder numbers of ``u``'s subtree form the contiguous
    interval ``[pre[u], pre[u] + size[u] - 1]`` (per tree) — returns
    (lo, hi), vectorized over ``u``."""
    u = np.asarray(u)
    lo = np.asarray(preorder)[u]
    return lo, lo + np.asarray(subtree_size)[u] - 1


def roots_and_sizes(parent: np.ndarray):
    """(root_of, tree_size_of) per node, by vectorized pointer jumping
    on the parent array (host-side, O(n log depth))."""
    parent = np.asarray(parent, np.int64)
    n = parent.shape[0]
    is_root = parent == np.arange(n)
    root_of = parent.copy()
    for _ in range(max(int(n).bit_length(), 1) + 1):
        if np.all(is_root[root_of]):
            break
        root_of = root_of[root_of]
    # jumping collapses even-length cycles to spurious fixed points, so
    # convergence must be judged against the ORIGINAL self-parented set
    # (same rule as rank_list_seq's cycle check).
    if not np.all(is_root[root_of]):
        raise ValueError("parent pointers contain a cycle")
    sizes = np.bincount(root_of, minlength=n)
    return root_of, sizes[root_of]


def _check_parent(parent) -> np.ndarray:
    parent = np.asarray(jax.device_get(parent)).astype(np.int64)
    n = parent.shape[0]
    if n == 0 or not ((parent >= 0) & (parent < n)).all():
        raise ValueError("parent must be a nonempty array of node ids")
    return parent


def _ranked_tour(parent, mesh, pe_axes, cfg, weighted, **kw):
    """Build the device tour, rank it, return host rank values trimmed
    to the 2n real arc slots."""
    succ, w, n_pad = euler.build_tour(parent, mesh, pe_axes=pe_axes,
                                      cfg=cfg, weighted=weighted,
                                      tracer=kw.get("tracer"))
    _, rank, stats = rank_list_with_stats(succ, w, mesh, pe_axes=pe_axes,
                                          cfg=cfg, **kw)
    n = parent.shape[0]
    return np.asarray(jax.device_get(rank))[:2 * n].astype(np.int64), stats


def node_depth(parent, mesh, pe_axes=None, cfg: ListRankConfig | None = None,
               **kw) -> np.ndarray:
    """Every node's depth (0 at its root), one ±1-weighted solve."""
    parent = _check_parent(parent)
    rpm, _ = _ranked_tour(parent, mesh, pe_axes, cfg, weighted=True, **kw)
    nodes = np.arange(parent.shape[0])
    nonroot = parent != nodes
    depth = np.zeros(parent.shape[0], np.int64)
    depth[nonroot] = 2 - rpm[euler.down(nodes[nonroot])]
    return depth


def subtree_size(parent, mesh, pe_axes=None,
                 cfg: ListRankConfig | None = None, **kw) -> np.ndarray:
    """Every node's subtree size, one unit-weighted solve."""
    parent = _check_parent(parent)
    r1, _ = _ranked_tour(parent, mesh, pe_axes, cfg, weighted=False, **kw)
    nodes = np.arange(parent.shape[0])
    nonroot = parent != nodes
    _, tree_size = roots_and_sizes(parent)
    size = tree_size.astype(np.int64).copy()  # roots: whole tree
    c = nodes[nonroot]
    size[c] = (r1[euler.down(c)] - r1[euler.up(c)] + 1) // 2
    return size


def tree_stats(parent, mesh, pe_axes=None, cfg: ListRankConfig | None = None,
               **kw) -> TreeStats:
    """All per-node statistics from ONE batched mesh solve.

    The unit- and ±1-weighted tours share the successor structure, so
    they ride as two instances of the batched front door — a single
    jitted solver invocation covers both weightings.
    """
    from repro.core.treealg import batch as batch_lib
    parent = _check_parent(parent)
    n = parent.shape[0]
    nodes = np.arange(n)
    nonroot = parent != nodes
    root_of, tree_size = roots_and_sizes(parent)

    succ_d, wpm_d, _ = euler.build_tour(parent, mesh, pe_axes=pe_axes,
                                        cfg=cfg, weighted=True,
                                        tracer=kw.get("tracer"))
    succ = np.asarray(jax.device_get(succ_d))[:2 * n]
    wpm = np.asarray(jax.device_get(wpm_d))[:2 * n]
    w1 = np.abs(wpm)  # unit weights: same tour, same zeroed terminals
    ranked, stats = batch_lib.rank_lists_with_stats(
        [(succ, w1), (succ, wpm)], mesh, pe_axes=pe_axes, cfg=cfg, **kw)
    r1 = ranked[0][1].astype(np.int64)
    rpm = ranked[1][1].astype(np.int64)

    depth = np.zeros(n, np.int64)
    size = tree_size.astype(np.int64).copy()
    pre = np.zeros(n, np.int64)
    post = np.maximum(tree_size.astype(np.int64) - 1, 0)
    c = nodes[nonroot]
    rd, ru = r1[euler.down(c)], r1[euler.up(c)]
    depth[c] = 2 - rpm[euler.down(c)]
    size[c] = (rd - ru + 1) // 2
    arcs_of_tree = 2 * (tree_size[c].astype(np.int64) - 1)
    pos_down = arcs_of_tree - 1 - rd
    pos_up = arcs_of_tree - 1 - ru
    pre[c] = (pos_down + 1 + depth[c]) // 2
    post[c] = (pos_up + 2 - depth[c]) // 2 - 1
    return TreeStats(parent=parent, root_of=root_of, depth=depth,
                     subtree_size=size, preorder=pre, postorder=post,
                     stats=stats)


def preorder(parent, mesh, **kw) -> np.ndarray:
    """0-based per-tree preorder numbers (ascending-id child order)."""
    return tree_stats(parent, mesh, **kw).preorder


def postorder(parent, mesh, **kw) -> np.ndarray:
    """0-based per-tree postorder numbers (ascending-id child order)."""
    return tree_stats(parent, mesh, **kw).postorder


def root_tree(parent, new_root: int, mesh, pe_axes=None,
              cfg: ListRankConfig | None = None, **kw) -> np.ndarray:
    """Re-orient a rooted tree's edges toward ``new_root``.

    The circular Euler tour is cut at ``down(new_root)``
    (``euler.build_tour(cut_at=new_root)``); after ranking, edge
    {c, q=parent[c]} keeps its orientation iff the (q→c) arc precedes
    (c→q) in the new tour — i.e. ``rank1(down(c)) > rank1(up(c))`` —
    and flips otherwise. Exactly the edges on the old-root→new-root
    path flip.
    """
    parent = _check_parent(parent)
    n = parent.shape[0]
    nodes = np.arange(n)
    roots = nodes[parent == nodes]
    if roots.size != 1:
        raise ValueError("root_tree requires a single-tree input")
    if not 0 <= new_root < n:
        raise ValueError("new_root out of range")
    if new_root == int(roots[0]):
        return parent.copy()
    succ, w, _ = euler.build_tour(parent, mesh, pe_axes=pe_axes, cfg=cfg,
                                  cut_at=int(new_root),
                                  tracer=kw.get("tracer"))
    _, rank, _ = rank_list_with_stats(succ, w, mesh, pe_axes=pe_axes,
                                      cfg=cfg, **kw)
    r1 = np.asarray(jax.device_get(rank))[:2 * n].astype(np.int64)
    out = np.full(n, -1, np.int64)
    c = nodes[parent != nodes]
    q = parent[c]
    keep = r1[euler.down(c)] > r1[euler.up(c)]
    out[c[keep]] = q[keep]
    out[q[~keep]] = c[~keep]
    out[new_root] = new_root
    if (out < 0).any():
        raise AssertionError("re-rooting left unoriented nodes")
    return out
