"""Public API: distributed list ranking over a JAX mesh.

``rank_list(succ, rank, mesh, ...)`` runs the paper's engineered
pipeline:

  1. local contraction of PE-local sublists (§2.3, optional),
  2. sparse-ruling-set with spawning, ``srs_rounds`` recursion levels,
     pointer doubling base case (§2.1-2.2); or plain pointer doubling,
  3. direction handling: §2.5 terminal→initial postprocess (default) or
     the faithful Algorithm-1 reversal preprocessing,
  4. restoration of locally contracted elements.

Every capacity (mailboxes, queues, subproblem stores) is host-derived
from the instance parameters with configurable slack; runs that hit any
capacity report it in ``stats`` and the driver retries, doubling only
the capacity family whose fatal stat fired (tuner.escalate). Capacity
therefore affects only performance, never correctness. Parameter
defaults (ruler fractions, indirection, SRS-vs-PD) can be derived from
the §2.6 cost model — see repro.core.listrank.tuner.
"""
from __future__ import annotations

import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.listrank import local as local_lib
from repro.core.listrank import store as store_lib
from repro.core.listrank import transport as transport_lib
from repro.core.listrank import tuner
from repro.core.listrank.config import IndirectionSpec, ListRankConfig
from repro.core.listrank.doubling import doubling_solve
from repro.core.listrank import exchange as exchange_lib
from repro.core.listrank.exchange import MeshPlan
from repro.core.listrank.srs import (LevelSpec, gather_until_done,
                                     route_until_done, solve_store,
                                     zero_stats, _merge)
from repro.core.listrank import resume as resume_lib
from repro.core.listrank.resume import FATAL_KEYS, SolveExhausted  # noqa: F401
from repro.obs import telemetry as tele_lib
from repro.obs import trace as trace_lib
# (re-exported: graphalg.frontdoor composes FATAL_KEYS; callers catch
# SolveExhausted from either module.)


def chase_leaves(weight_dtype=jnp.float32) -> dict:
    """Structure of a chase wave message for a given weight dtype.

    The weight leaf rides as whatever dtype the caller's rank array
    carries (int32 for the ±1 Euler-tour weights of
    ``repro.core.treealg``, float32 for float instances); the wire
    format bit-reinterprets it, so e.g. int32 ±1 weights round-trip
    exactly — no float detour anywhere in the solver.
    """
    return {"target": jnp.int32, "ruler": jnp.int32,
            "weight": jnp.dtype(weight_dtype)}


def chase_wire_words(weight_dtype=jnp.float32) -> int:
    """int32 words per chase message on the wire (payload leaves +
    routing destination + validity) — the WireFormat descriptor derived
    host-side; the benchmark harness uses it for modeled comm volume.
    Every supported weight dtype packs to one 32-bit word, so the width
    is dtype-independent."""
    return exchange_lib.WireFormat.for_leaves(
        {**chase_leaves(weight_dtype), "_dest": jnp.int32}).width


#: the default-dtype descriptors (kept as module constants for the
#: benchmark harnesses' modeled-volume computations).
CHASE_LEAVES = chase_leaves()
CHASE_WIRE_WORDS = chase_wire_words()


def canonical_weight_dtype(dtype) -> jnp.dtype:
    """The on-device dtype for a rank/weight input: 32-bit words,
    integer kinds to int32, float kinds to float32 (bool weights make
    no sense and are rejected)."""
    dt = jnp.dtype(dtype)
    if jnp.issubdtype(dt, jnp.floating):
        return jnp.dtype(jnp.float32)
    if jnp.issubdtype(dt, jnp.integer):
        return jnp.dtype(jnp.int32)
    raise TypeError(f"unsupported weight dtype {dt}")


def build_specs(cfg: ListRankConfig, plan: MeshPlan, m: int, n: int,
                term_bound: int,
                scales=tuner.CapacityScales(),
                estimate: tuner.CapacityEstimate | None = None,
                ) -> tuple[LevelSpec, ...]:
    """Host-side derivation of every static capacity (see module doc).

    Per-level ruler fractions come from :func:`tuner.level_plan` — the
    cost model when ``cfg.ruler_fraction is None``, the fixed fraction
    otherwise. ``scales`` carries the targeted retry multipliers
    (chase mail/queue, sub store, gather) from the driver's retry loop —
    either one :class:`tuner.CapacityScales` for every level or a
    per-level sequence (``srs_rounds`` chase levels + the base level;
    level-resume escalates only levels >= the faulting one, so completed
    levels' static shapes never change). ``estimate`` (the sampled-
    splitter pre-pass, :func:`tuner.estimate_capacities`) replaces the
    static ``cfg.capacity_slack`` guess with the measured per-hop
    destination skew for the mailbox families.
    """
    levels = tuner.level_plan(cfg, plan.p, plan.indirection.depth, n)
    level_scales = tuner.normalize_level_scales(scales, cfg.srs_rounds + 1)

    def hop_slack(hi: int) -> float:
        return (estimate.slack_for_hop(hi) if estimate is not None
                else cfg.capacity_slack)

    specs: list[LevelSpec] = []
    cap = m
    tb = term_bound
    p = plan.p
    logp = math.log2(max(p, 2))
    for li, lp in enumerate(levels):
        sc = level_scales[li]
        frac = lp.frac
        r_static = max(cfg.min_rulers_per_pe, int(math.ceil(frac * cap)))
        mail_caps = tuple(
            max(cfg.min_capacity,
                int(math.ceil(hop_slack(hi) * sc.chase * r_static
                              / plan.hop_size(hop))))
            for hi, hop in enumerate(plan.indirection.hops))
        inbox = sum(plan.hop_size(h) * c
                    for h, c in zip(plan.indirection.hops, mail_caps))
        queue_cap = int(max(cfg.queue_slack * r_static * sc.chase,
                            2 * inbox + cfg.spawn_window + 64))
        # rounds ~ n/r + log p (DESIGN.md §2); 1/frac is the per-PE n/r.
        max_rounds = int(cfg.max_round_slack * (1.0 / frac + logp) + 256)
        exp_sub = r_static * (1.0 + math.log(max(1.0 / frac, 2.0))) + tb + 64
        cap_sub = min(cap, int(math.ceil(cfg.sub_capacity_slack * sc.sub
                                         * exp_sub)))
        gcap = tuple(
            max(cfg.min_capacity,
                int(math.ceil(hop_slack(hi) * sc.gather * cap
                              / plan.hop_size(hop))))
            for hi, hop in enumerate(plan.indirection.hops))
        specs.append(LevelSpec(
            cap=cap, r_static=r_static, mail_caps=mail_caps,
            queue_cap=queue_cap, spawn_window=cfg.spawn_window,
            max_rounds=max_rounds, cap_sub=cap_sub,
            gather_req_cap=gcap, gather_resp_cap=gcap, base=False,
            ruler_frac=frac, max_restarts=cfg.max_restarts))
        cap = cap_sub
        tb = cap_sub  # every sub element may be a sub-terminal
    # base level (pointer doubling or all-gather)
    sc = level_scales[-1]
    gcap = tuple(
        max(cfg.min_capacity,
            int(math.ceil(hop_slack(hi) * sc.gather * cap
                          / plan.hop_size(hop))))
        for hi, hop in enumerate(plan.indirection.hops))
    specs.append(LevelSpec(
        cap=cap, r_static=0, mail_caps=(0,) * plan.indirection.depth,
        queue_cap=0, spawn_window=0,
        max_rounds=int(math.ceil(math.log2(max(n, 2)))) + 8, cap_sub=0,
        gather_req_cap=gcap, gather_resp_cap=gcap, base=True,
        ruler_frac=0.0, max_restarts=cfg.max_restarts))
    return tuple(specs)


# --------------------------------------------------------------------------
# the per-PE program (runs under shard_map)
# --------------------------------------------------------------------------

def _reverse_instance(plan, spec, owner_of, st, stats):
    """Faithful Algorithm-1 preprocessing: build the reversed instance
    with one n-message exchange (the cost §2.5 avoids)."""
    cap = st.ids.shape[0]
    gid = st.ids
    nonterm = st.valid & (st.succ != gid)
    payload = {"target": st.succ, "src": gid, "w": st.rank}
    dest = owner_of(st.succ).astype(jnp.int32)

    got = jnp.zeros(cap, jnp.bool_)
    succ_rev = jnp.where(st.valid, gid, st.succ)
    rank_rev = jnp.zeros_like(st.rank)

    def deliver(carry, delivered, dval):
        got, succ_rev, rank_rev = carry
        slots, found = store_lib.slot_of(st, delivered["target"])
        ok = dval & found
        idx = jnp.where(ok, slots, cap)
        got = got.at[idx].set(True, mode="drop")
        succ_rev = succ_rev.at[idx].set(delivered["src"], mode="drop")
        rank_rev = rank_rev.at[idx].set(delivered["w"], mode="drop")
        return got, succ_rev, rank_rev

    (got, succ_rev, rank_rev), pending, msgs, rtele = route_until_done(
        plan, spec.mail_caps, payload, dest, nonterm, deliver,
        (got, succ_rev, rank_rev))
    upd = {"reversal_msgs": msgs, "undelivered": pending}
    if plan.telemetry:
        # the reversal exchange rides the chase-family mail caps
        upd["telemetry"] = {"chase": rtele}
    stats = _merge(stats, upd)
    rev = st.replace(succ=succ_rev, rank=rank_rev)
    return rev, stats


def _restore_local(plan, spec, owner_of, st, aux, rep, succ_orig, rank_orig,
                   base, stats):
    """Restore locally contracted elements (§2.3 restoration).

    R1: every rep's solved succ points to a contracted-instance terminal
        l_t whose local chain continues to the true terminal — fetch the
        tail (terminal id, tail distance) from l_t's owner (aggregated).
    R2: interior elements splice their local-chain prefix onto the fixed
        final values of the rep their chain exits into.
    """
    m = succ_orig.shape[0]
    lidx = jnp.arange(m, dtype=jnp.int32)
    gid = base + lidx

    # ---- R1: tail fixup for reps
    tail_fn = local_lib.tail_lookup(aux, succ_orig, rank_orig, base)
    resp, answered, g1 = gather_until_done(
        plan, st.succ, rep, owner_of, tail_fn,
        spec.gather_req_cap, spec.gather_resp_cap, dedup=True)
    upd = answered & resp["found"] & rep
    final_succ = jnp.where(upd, resp["succ"], st.succ)
    final_rank = jnp.where(upd, st.rank + resp["rank"], st.rank)
    miss1 = plan.psum(jnp.sum(rep & ~upd).astype(jnp.int32))

    # ---- R2: interior elements
    S, D, stop_is_term = aux["S"], aux["D"], aux["stop_is_term"]
    interior = ~rep
    # chains ending at a true local terminal need no communication
    direct = interior & stop_is_term
    final_succ = jnp.where(direct, base + S, final_succ)
    final_rank = jnp.where(direct, D, final_rank)
    # chains exiting the PE: ask the rep the chain enters (aggregated)
    need = interior & ~stop_is_term
    exit_target = succ_orig[S]  # the remote rep

    def final_fn(gids, valid):
        slots = jnp.clip(gids - base_ref[0], 0, m - 1).astype(jnp.int32)
        ok = valid & (gids >= base_ref[0]) & (gids < base_ref[0] + m)
        return {"succ": jnp.where(ok, final_succ_ref[0][slots], gids),
                "rank": jnp.where(ok, final_rank_ref[0][slots],
                                  jnp.zeros_like(final_rank_ref[0][slots])),
                "found": ok}

    # lookup closes over the *fixed* rep finals on the owner side
    base_ref = [base]
    final_succ_ref = [final_succ]
    final_rank_ref = [final_rank]
    resp2, answered2, g2 = gather_until_done(
        plan, exit_target, need, owner_of, final_fn,
        spec.gather_req_cap, spec.gather_resp_cap, dedup=True)
    upd2 = answered2 & resp2["found"] & need
    final_succ = jnp.where(upd2, resp2["succ"], final_succ)
    final_rank = jnp.where(upd2, D + rank_orig[S] + resp2["rank"], final_rank)
    miss2 = plan.psum(jnp.sum(need & ~upd2).astype(jnp.int32))

    upd = {
        "fixup_msgs": g1["msgs"] + g2["msgs"],
        "undelivered": g1["undelivered"] + g2["undelivered"] + miss1 + miss2}
    if plan.telemetry:
        upd["telemetry"] = {"gather": tele_lib.merge(g1["telemetry"],
                                                     g2["telemetry"])}
    stats = _merge(stats, upd)
    return final_succ, final_rank, stats


def _solve_sharded(succ, rank, seed, *, plan: MeshPlan, cfg: ListRankConfig,
                   specs: list[LevelSpec], m: int):
    pe = plan.my_id().astype(jnp.int32)
    base = pe * m
    lidx = jnp.arange(m, dtype=jnp.int32)
    gid = base + lidx
    key = jax.random.PRNGKey(seed)
    stats = zero_stats()
    if plan.telemetry:
        stats["telemetry"] = tele_lib.stage_zero(plan.indirection.depth)

    def owner_of(g):
        return g // m

    succ_orig, rank_orig = succ, rank
    if cfg.local_contraction:
        succ_w, rank_w, rep, aux = local_lib.contract(
            succ, rank, base, m, cfg.use_pallas)
        active = rep
    else:
        succ_w, rank_w, rep, aux = succ, rank, None, None
        active = jnp.ones(m, jnp.bool_)

    is_term0 = active & (succ_w == gid)
    spec0 = specs[0]

    if cfg.algorithm == "doubling":
        st = store_lib.make_dense_store(succ_w, rank_w, active, base)
        st, pst = doubling_solve(plan, st, owner_of, spec0.gather_req_cap,
                                 spec0.gather_resp_cap,
                                 specs[-1].max_rounds, cfg.dedup_requests)
        upd = {"pd_rounds": pst["pd_rounds"],
               "pd_msgs": pst["pd_msgs"],
               "undelivered": pst["pd_undelivered"]}
        if plan.telemetry:
            upd["telemetry"] = {"gather": pst["telemetry"]}
        stats = _merge(stats, upd)
    elif cfg.avoid_reversal:
        # forward chasing; the per-level direction flip at level 0 is
        # exactly the paper's §2.5 reversal-avoiding postprocess.
        st = store_lib.make_dense_store(succ_w, rank_w, active, base)
        st, stats = solve_store(plan, cfg, specs, owner_of, st, key, 0, stats,
                                want_sink=True)
    else:
        st = store_lib.make_dense_store(succ_w, rank_w, active, base)
        st, stats = _reverse_instance(plan, spec0, owner_of, st, stats)
        forced = is_term0  # Alg.1 l.2: initial elements of the reversed
        # instance are the original terminals — locally known.
        st, stats = solve_store(plan, cfg, specs, owner_of, st, key, 0, stats,
                                forced=forced, want_sink=False)

    if cfg.local_contraction:
        succ_f, rank_f, stats = _restore_local(
            plan, spec0, owner_of, st, aux, rep, succ_orig, rank_orig, base,
            stats)
    else:
        succ_f, rank_f = st.succ, st.rank

    # make stats replicated for a P() out-spec; telemetry stays per-PE
    # (popped before the psum — the count pins require the telemetry-on
    # program to add zero collectives).
    tele = stats.pop("telemetry", None)
    stats = {k: plan.psum(v) for k, v in stats.items()}
    if tele is not None:
        return succ_f, rank_f, stats, jax.tree.map(lambda v: v[None], tele)
    return succ_f, rank_f, stats


# --------------------------------------------------------------------------
# host driver
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=256)
def _jitted_solver(mesh, plan, cfg, specs, m):
    fn = functools.partial(_solve_sharded, plan=plan, cfg=cfg, specs=specs,
                           m=m)
    spec_sharded = P(plan.pe_axes)
    out_specs = (spec_sharded, spec_sharded, P())
    if plan.telemetry:
        out_specs = out_specs + (spec_sharded,)
    return transport_lib.device_run(
        mesh, plan.pe_axes, fn,
        in_specs=(spec_sharded, spec_sharded, P()),
        out_specs=out_specs)


def rank_list_with_stats(succ, rank, mesh, pe_axes: Sequence[str] | None = None,
                         cfg: ListRankConfig | None = None,
                         indirection: IndirectionSpec | None = None,
                         seed: int = 0, max_retries: int = 3,
                         term_bound: int | None = None,
                         supervisor=None, inject=None,
                         stage_counters: bool = False, initial_scales=None,
                         tracer=None):
    """Rank lists distributed over ``mesh``. Returns (succ, rank, stats).

    ``succ``/``rank`` may be numpy or jax arrays of length n (divisible
    by the PE count); they are placed block-sharded over ``pe_axes``.

    The solve runs as the level-resumable stage loop
    (:mod:`repro.core.listrank.resume`): a fatal capacity overflow at
    level k resumes from the end of level k-1 with only the implicated
    family escalated. ``supervisor``
    (:class:`repro.runtime.fault_tolerance.SolveSupervisor`) adds
    level-boundary checkpoints, preemption handling, and restore-on-
    restart; ``inject`` (:class:`repro.core.listrank.faults.FaultSpec`
    or a sequence) drives deterministic fault injection;
    ``stage_counters`` records per-stage collective counts;
    ``initial_scales`` pre-seeds the per-level capacity scales
    (CapacityScales or a per-level sequence). A run that exhausts its
    escalation budget raises :class:`SolveExhausted` carrying the full
    escalation path and the per-family fatal stats.

    ``tracer`` (a :class:`repro.obs.Tracer`) records the flight-recorder
    span tree for the whole solve — the root ``solve`` span, the
    capacity-estimation pre-pass, every stage execution/retry with
    measured wall time and §2.6 predicted time, and checkpoint
    save/restore — and ingests the final ``host_stats`` into the
    tracer's metrics registry. Host-side only; the traced programs are
    bit-identical with tracing on or off.
    """
    cfg = cfg or ListRankConfig()
    pe_axes = tuple(pe_axes) if pe_axes is not None else tuple(mesh.axis_names)
    backend, mesh = transport_lib.resolve_backend(cfg.backend, mesh, pe_axes)
    if backend == "simshard":
        transport_lib.check_sim_config(cfg)
    n = succ.shape[0]
    if indirection is None and cfg.auto_indirection:
        axis_sizes = tuple(mesh.shape[a] for a in pe_axes)
        indirection = tuner.choose_indirection(cfg, pe_axes, axis_sizes, n)
    plan = MeshPlan.from_mesh(mesh, pe_axes, indirection,
                              wire_packing=cfg.wire_packing,
                              pallas_pack=cfg.use_pallas_pack,
                              telemetry=cfg.telemetry)
    p = plan.p
    if n % p != 0:
        raise ValueError(f"n={n} must be divisible by p={p} (pad the input)")
    m = n // p
    if cfg.algorithm == "auto":
        # Corollary-1 regime check: PD below the efficiency threshold.
        cfg = cfg.with_(algorithm=tuner.choose_algorithm(
            cfg, p, plan.indirection.depth, m))
    s_host = None
    if term_bound is None:
        s_host = np.asarray(jax.device_get(succ))
        owners = np.arange(n) // m
        counts = np.bincount(owners[s_host == np.arange(n)], minlength=p)
        term_bound = int(counts.max()) if counts.size else 0

    tr = trace_lib.ensure(tracer)
    solve_span = tr.begin(
        "solve", cat="solve", n=n, p=p, backend=backend,
        algorithm=cfg.algorithm, machine=cfg.machine.name,
        indirection=[list(h) for h in plan.indirection.hops])
    try:
        estimate = None
        if cfg.capacity_estimation:
            # sampled-splitter pre-pass: size mailboxes for the measured
            # destination skew instead of the static slack guess.
            if s_host is None:
                s_host = np.asarray(jax.device_get(succ))
            with tr.span("estimate_capacities", cat="tuner") as est_span:
                estimate = tuner.estimate_capacities(s_host, plan, m, cfg,
                                                     seed=seed)
                est_span.annotate(sample_size=estimate.sample_size,
                                  hop_slack=list(estimate.hop_slack),
                                  max_frac=list(estimate.max_frac))

        succ_d = transport_lib.put_sharded(mesh, pe_axes,
                                           jnp.asarray(succ, jnp.int32))
        # explicit weight-dtype canonicalization (chase_leaves): int
        # weights stay integer end-to-end — ±1 tour weights round-trip
        # exactly.
        wdt = canonical_weight_dtype(
            rank.dtype if hasattr(rank, "dtype") else np.asarray(rank).dtype)
        rank_d = transport_lib.put_sharded(mesh, pe_axes,
                                           jnp.asarray(rank, wdt))

        def build_level_specs(level_scales):
            return build_specs(cfg, plan, m, n, term_bound,
                               scales=level_scales, estimate=estimate)

        if tr.enabled and cfg.algorithm == "srs":
            from repro.obs import cost as cost_lib
            lp = tuner.level_plan(cfg, p, plan.indirection.depth, n)
            solve_span.annotate(predicted_solve_s=cost_lib.predict_solve(
                n, plan, cfg.machine, r_total=lp[0].r_total))

        succ_f, rank_f, host_stats = resume_lib.run_staged(
            succ_d, rank_d, mesh=mesh, plan=plan, cfg=cfg, m=m, n=n,
            seed=seed, build_level_specs=build_level_specs,
            max_retries=max_retries, supervisor=supervisor, inject=inject,
            stage_counters=stage_counters, initial_scales=initial_scales,
            tracer=tracer)
    except BaseException as e:
        tr.end(solve_span, outcome=type(e).__name__)
        raise
    tr.end(solve_span, outcome="ok", attempts=host_stats["attempts"])
    if "telemetry" in host_stats and estimate is not None:
        # back-test the sampled-splitter DKW margins against the skew
        # the solve actually observed (EXPERIMENTS.md §telemetry).
        recs = [tele_lib.StageRecord.from_json(d)
                for d in host_stats["telemetry"]["stages"]]
        host_stats["telemetry"]["dkw"] = tele_lib.dkw_backtest(
            list(estimate.max_frac), int(estimate.sample_size),
            [plan.hop_size(h) for h in plan.indirection.hops], recs)
    if tr.enabled:
        from repro.obs import metrics as metrics_lib
        metrics_lib.ingest_host_stats(tr.metrics, host_stats)
    return succ_f, rank_f, host_stats


def rank_list(succ, rank, mesh, **kw):
    """Convenience wrapper: returns (succ, rank) only."""
    succ_f, rank_f, _ = rank_list_with_stats(succ, rank, mesh, **kw)
    return succ_f, rank_f
