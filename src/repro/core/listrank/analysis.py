"""Scalability cost model (paper §2.6) and parameter selection.

The paper models a d-level indirect all-to-all with at most h words per
PE as  T_all2all(p,h,d) = alpha*d*p^(1/d) + beta*d*h  and derives

  T(n,p,r) = O( d*beta*n/p + alpha*d*p^(1/d) * n/r
                + alpha*d*p^(1/d)*log p + beta*d*r*log^2(p)/p )

with the optimum  r* = Theta( sqrt(alpha*n*p^(1+1/d)/beta) / log p ).

The model is consumed by :mod:`repro.core.listrank.tuner` for (a) the
per-level ruler counts when ``ListRankConfig.ruler_fraction is None``
plus indirection/algorithm selection, (b) the benchmark harness's
modeled communication times (this container measures a single CPU, so
wall-clock alpha effects are modeled from counted messages with
machine constants), and (c) the EXPERIMENTS.md validation of the
paper's round/subproblem predictions.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class MachineModel:
    """alpha/beta in seconds (per message startup / per 8-byte word)."""
    alpha: float
    beta: float
    name: str = "generic"


#: OmniPath-like cluster (SuperMUC-NG thin nodes; paper's platform).
SUPERMUC = MachineModel(alpha=2.0e-6, beta=8.0 / 100e9 * 8, name="supermuc-ng")
#: TPU v5e ICI: per-collective issue overhead vs 50 GB/s/link.
TPU_V5E_ICI = MachineModel(alpha=1.0e-6, beta=8.0 / 50e9, name="tpu-v5e-ici")
#: intra-node (shared memory / NVLink-class) for topology-aware hops.
INTRA_NODE = MachineModel(alpha=4.0e-7, beta=8.0 / 200e9, name="intra-node")


def t_all2all(p: int, h: float, d: int, m: MachineModel) -> float:
    """Paper's model for one d-level indirect all-to-all, h words/PE."""
    return m.alpha * d * p ** (1.0 / d) + m.beta * d * h


def r_star(n: int, p: int, d: int, m: MachineModel) -> int:
    """Optimal total ruler count (Observation 1)."""
    logp = max(math.log2(max(p, 2)), 1.0)
    r = math.sqrt(m.alpha * n * p ** (1.0 + 1.0 / d) / m.beta) / logp
    return max(p, min(int(r), n))


def t_model(n: int, p: int, r: int, d: int, m: MachineModel,
            n_prime: float | None = None) -> float:
    """Predicted SRS running time T(n,p,r) with a PD base case."""
    logp = max(math.log2(max(p, 2)), 1.0)
    if n_prime is None:
        n_prime = expected_subproblem(n, r)
    t_chase = d * m.beta * n / p + m.alpha * d * p ** (1.0 / d) * (n / max(r, 1))
    t_base = math.log2(max(n_prime, 2)) * (
        m.alpha * d * p ** (1.0 / d) + m.beta * d * n_prime / p)
    return t_chase + t_base


def t_hops(n: int, p: int, r: int, hop_sizes: "tuple[int, ...]",
           hop_machines: "tuple[MachineModel, ...]") -> float:
    """Generalization of :func:`t_model` to an explicit hop decomposition
    with per-hop machine constants (topology-aware indirection routes
    its first hop over intra-node links, which have a different alpha).

    One routing round costs ``sum_h alpha_h * hop_size(h)`` in startups
    (each hop is a dense all_to_all over its peer group) and every
    message crosses every hop, so the volume coefficient is
    ``sum_h beta_h``. Used by ``tuner.choose_indirection``.
    """
    logp = max(math.log2(max(p, 2)), 1.0)
    startup = sum(m.alpha * s for s, m in zip(hop_sizes, hop_machines))
    beta_eff = sum(m.beta for m in hop_machines)
    rounds = n / max(r, 1) + logp
    n_prime = expected_subproblem(n, r)
    t_chase = beta_eff * n / p + startup * rounds
    t_base = math.log2(max(n_prime, 2)) * (startup + beta_eff * n_prime / p)
    return t_chase + t_base


def expected_subproblem(n: int, r: int) -> float:
    """E[#rulers] with spawning ~= r * ln(n/r) (Sibeyn; paper §2.2)."""
    if r <= 0 or r >= n:
        return float(n)
    return r * max(math.log(n / r), 1.0)


def expected_rounds(n: int, r: int) -> float:
    """Chase rounds ~= n/r + 1 w.h.p. for r >> p log p (paper §2.2)."""
    return n / max(r, 1) + 1.0


def efficiency_threshold(p: int, d: int, m: MachineModel) -> float:
    """Corollary 1: the algorithm is efficient once
    n/p >> (alpha/beta) * p^(1/d) * log^2 p."""
    logp = max(math.log2(max(p, 2)), 1.0)
    return (m.alpha / m.beta) * p ** (1.0 / d) * logp ** 2
