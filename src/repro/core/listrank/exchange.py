"""Bucketed message routing over a JAX device mesh (paper §2.4).

The paper sends sparse point-to-point messages over MPI; on a TPU mesh
we realize each communication round as one (or ``d``, with indirection)
dense, fixed-capacity ``all_to_all`` per hop. A *hop* fixes the
destination coordinate along one mesh-axis group. Direct delivery is a
single hop over all PE axes; grid indirection is one hop per axis
(minor axis first — the paper's column-then-row routing); topology-aware
indirection hops over the intra-node axis first.

Static shapes force a per-peer mailbox capacity. Messages that do not
fit are *leftovers*: they stay on the holding PE and re-enter routing in
the caller's next round (re-routing from an intermediate PE is correct
because every hop fixes its own coordinate, so partially-routed messages
simply self-send on already-fixed hops). Capacity overflow therefore
costs rounds, never correctness; the amount is tracked in ``stats``.

Packed wire format (see DESIGN.md): with ``MeshPlan.wire_packing`` all
payload leaves of a message batch are bit-packed into a single
``(Q, W)`` int32 matrix — the layout is a static :class:`WireFormat`
derived from the payload pytree at trace time — so each hop costs
exactly **one** ``all_to_all`` regardless of leaf count. The unpacked
path (one collective per leaf plus one for validity) is kept behind the
same API for A/B testing; both paths share every index computation, so
they are bit-identical.

Sorting discipline: the only O(Q log Q) sort in the routing hot path is
the per-hop bucket sort (:func:`sort_and_group`, shared with request
deduplication in :func:`remote_gather`). Queue compaction is sort-free
(stream compaction by prefix sum), and :func:`route_compact` fuses it
into the bucket sort — leftovers come out compacted for free.

All functions here run *inside* ``shard_map`` — per-PE arrays,
collectives by axis name.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.listrank.config import IndirectionSpec
from repro.core.listrank import transport as transport_lib
from repro.obs import telemetry as tele_lib

Pytree = Any

INT_MAX = jnp.iinfo(jnp.int32).max

#: payload keys reserved for the router itself.
RESERVED_KEYS = ("_dest", "_src")


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """Static routing metadata for a PE grid embedded in a mesh.

    PE ids are flattened row-major over ``pe_axes`` (matching
    ``axis_index`` over the full axis tuple).

    ``wire_packing`` selects the packed wire format (one collective per
    hop); ``pallas_pack`` additionally routes the pack+bucket-scatter
    through the ``repro.kernels.mailbox_pack`` Pallas kernel.

    ``transport`` is how the program reaches the interconnect: raw mesh
    collectives under ``shard_map``, or the simshard virtual-PE
    emulation under ``vmap`` (see :mod:`repro.core.listrank.transport`).
    Every collective in this package goes through the :meth:`my_id` /
    :meth:`all_to_all` / :meth:`psum` / :meth:`all_gather` delegates —
    nothing may call ``lax`` collectives directly (enforced by
    ``tests/test_transport_audit.py``).
    """

    pe_axes: tuple[str, ...]
    axis_sizes: tuple[int, ...]
    indirection: IndirectionSpec
    wire_packing: bool = True
    pallas_pack: bool = False
    transport: transport_lib.Transport = transport_lib.MeshTransport()
    #: mirror of ``ListRankConfig.telemetry`` (static; part of every
    #: jitted-program key through the plan). When set, routing emits a
    #: per-PE ``repro.obs.telemetry`` record in ``stats["telemetry"]``
    #: — pure local arithmetic, zero added collectives.
    telemetry: bool = False

    @property
    def p(self) -> int:
        out = 1
        for s in self.axis_sizes:
            out *= s
        return out

    def axis_size(self, name: str) -> int:
        return self.axis_sizes[self.pe_axes.index(name)]

    def hop_size(self, hop: tuple[str, ...]) -> int:
        out = 1
        for a in hop:
            out *= self.axis_size(a)
        return out

    def my_id(self) -> jax.Array:
        return self.transport.axis_index(self.pe_axes)

    def all_to_all(self, x: jax.Array, hop: tuple[str, ...],
                   split_axis: int, concat_axis: int) -> jax.Array:
        """One routing collective over the axis group ``hop``."""
        return self.transport.all_to_all(x, hop, split_axis, concat_axis,
                                         tiled=True)

    def psum(self, x):
        """Sum-reduce over every PE axis (stats and convergence tests)."""
        return self.transport.psum(x, self.pe_axes)

    def all_gather(self, x: jax.Array) -> jax.Array:
        """Tiled gather over every PE axis (allgather base case)."""
        return self.transport.all_gather(x, self.pe_axes, tiled=True)

    def hop_coord(self, pe_id: jax.Array, hop: tuple[str, ...]) -> jax.Array:
        """Coordinate of ``pe_id`` along the (possibly non-contiguous)
        axis group ``hop``, flattened row-major within the group."""
        coord = jnp.zeros_like(pe_id)
        for a in hop:
            i = self.pe_axes.index(a)
            stride = 1
            for s in self.axis_sizes[i + 1:]:
                stride *= s
            c = (pe_id // stride) % self.axis_sizes[i]
            coord = coord * self.axis_sizes[i] + c
        return coord

    def hop_coord_to_pe(self, hop: tuple[str, ...]) -> np.ndarray:
        """Inverse of :meth:`hop_coord` restricted to the group: the
        contribution of group coordinate ``b`` to the flat PE id (the
        remaining axes contribute the *receiver's own* coordinates).
        Static (numpy) — used to rebuild sender ids from receive-buffer
        row indices."""
        s = self.hop_size(hop)
        b = np.arange(s, dtype=np.int32)
        rem, acc = b, np.zeros(s, np.int32)
        for a in reversed(hop):
            i = self.pe_axes.index(a)
            stride = 1
            for sz in self.axis_sizes[i + 1:]:
                stride *= sz
            c = rem % self.axis_sizes[i]
            rem = rem // self.axis_sizes[i]
            acc = acc + c.astype(np.int32) * stride
        return acc

    @staticmethod
    def from_mesh(mesh, pe_axes: Sequence[str],
                  indirection: IndirectionSpec | None = None,
                  wire_packing: bool = True,
                  pallas_pack: bool = False,
                  transport: transport_lib.Transport | None = None,
                  telemetry: bool = False,
                  ) -> "MeshPlan":
        """Plan for a real mesh OR a :class:`transport.SimMesh` — the
        transport defaults to whichever backend the mesh object implies."""
        pe_axes = tuple(pe_axes)
        sizes = tuple(mesh.shape[a] for a in pe_axes)
        if indirection is None:
            indirection = IndirectionSpec.direct(pe_axes)
        for hop in indirection.hops:
            for a in hop:
                if a not in pe_axes:
                    raise ValueError(f"hop axis {a} not in pe_axes {pe_axes}")
        if transport is None:
            transport = (transport_lib.SimShardTransport()
                         if transport_lib.is_sim(mesh)
                         else transport_lib.MeshTransport())
        return MeshPlan(pe_axes=pe_axes, axis_sizes=sizes,
                        indirection=indirection, wire_packing=wire_packing,
                        pallas_pack=pallas_pack, transport=transport,
                        telemetry=telemetry)


# --------------------------------------------------------------------------
# wire format
# --------------------------------------------------------------------------

def to_wire_word(x: jax.Array) -> jax.Array:
    """Reinterpret a 32-bit-or-narrower leaf as int32 words, exactly."""
    dt = x.dtype
    if dt == jnp.int32:
        return x
    if dt in (jnp.float32, jnp.uint32):
        return lax.bitcast_convert_type(x, jnp.int32)
    if dt == jnp.bool_:
        return x.astype(jnp.int32)
    if jnp.issubdtype(dt, jnp.integer) and jnp.dtype(dt).itemsize < 4:
        return x.astype(jnp.int32)
    raise TypeError(f"wire format does not support dtype {dt}")


def from_wire_word(w: jax.Array, dtype) -> jax.Array:
    """Inverse of :func:`to_wire_word`."""
    dt = jnp.dtype(dtype)
    if dt == jnp.int32:
        return w
    if dt in (jnp.float32, jnp.uint32):
        return lax.bitcast_convert_type(w, dt)
    if dt == jnp.bool_:
        return w != 0
    return w.astype(dt)


@dataclasses.dataclass(frozen=True)
class WireFormat:
    """Static descriptor of the packed on-wire layout of a message batch.

    Each payload leaf of shape ``(Q, *trail)`` occupies
    ``prod(trail)`` int32 words per message; the final word is the
    validity flag. Leaves are laid out in sorted-key order so the format
    depends only on the payload *structure* — it is derived host-side
    (at trace time, and for capacity budgeting in ``build_specs``).
    """

    keys: tuple[str, ...]
    dtypes: tuple[str, ...]
    trails: tuple[tuple[int, ...], ...]

    @classmethod
    def from_payload(cls, payload: dict[str, jax.Array]) -> "WireFormat":
        keys = tuple(sorted(payload.keys()))
        dtypes, trails = [], []
        for k in keys:
            v = payload[k]
            dtypes.append(jnp.dtype(v.dtype).name)
            trails.append(tuple(int(d) for d in v.shape[1:]))
        return cls(keys=keys, dtypes=tuple(dtypes), trails=tuple(trails))

    @classmethod
    def for_leaves(cls, leaves: dict[str, Any]) -> "WireFormat":
        """Host-side construction from {name: dtype} scalar leaves."""
        keys = tuple(sorted(leaves.keys()))
        return cls(keys=keys,
                   dtypes=tuple(jnp.dtype(leaves[k]).name for k in keys),
                   trails=((),) * len(keys))

    def leaf_words(self, i: int) -> int:
        out = 1
        for d in self.trails[i]:
            out *= d
        return out

    @property
    def width(self) -> int:
        """Total int32 words per message, incl. the validity word."""
        return sum(self.leaf_words(i) for i in range(len(self.keys))) + 1

    def columns(self, payload: dict[str, jax.Array],
                valid: jax.Array) -> list[jax.Array]:
        """The ``width`` int32 columns of the wire matrix, unstacked."""
        q = valid.shape[0]
        cols: list[jax.Array] = []
        for i, k in enumerate(self.keys):
            w = to_wire_word(payload[k]).reshape(q, -1)
            cols.extend(w[:, j] for j in range(w.shape[1]))
        cols.append(valid.astype(jnp.int32))
        return cols

    def pack(self, payload: dict[str, jax.Array],
             valid: jax.Array) -> jax.Array:
        """Bit-pack a message batch into a ``(Q, width)`` int32 matrix."""
        return jnp.stack(self.columns(payload, valid), axis=1)

    def unpack(self, wire: jax.Array) -> tuple[dict[str, jax.Array], jax.Array]:
        """Inverse of :meth:`pack` (exact, incl. float bit patterns)."""
        return self.unpack_cols(wire.T)

    def unpack_cols(self, cols: jax.Array) -> tuple[dict[str, jax.Array],
                                                    jax.Array]:
        """Unpack from column-major wire words: ``cols`` is (width, R).

        This is the on-wire layout of the packed exchange — word-planes
        are contiguous, so packing/unpacking is plane-wise data movement
        with no transposes.
        """
        r = cols.shape[1]
        payload = {}
        off = 0
        for i, k in enumerate(self.keys):
            w = self.leaf_words(i)
            leaf = jnp.moveaxis(cols[off:off + w], 0, -1).reshape(
                (r,) + self.trails[i])
            payload[k] = from_wire_word(leaf, self.dtypes[i])
            off += w
        valid = cols[off] != 0
        return payload, valid


# --------------------------------------------------------------------------
# shared sort/scatter primitives
# --------------------------------------------------------------------------

def sort_and_group(key: jax.Array, valid: jax.Array, sentinel):
    """One stable sort, shared by bucketing and request dedup.

    Invalid rows sort to the back (keyed ``sentinel``, which must
    compare greater than every valid key). Returns

      order:  (Q,) the sort permutation,
      skey:   (Q,) keys in sorted order,
      pos:    (Q,) rank of each sorted row within its run of equal keys,
      newrun: (Q,) True at the first row of each run.
    """
    q = key.shape[0]
    k = jnp.where(valid, key, sentinel)
    order = jnp.argsort(k, stable=True)
    skey = k[order]
    i = jnp.arange(q, dtype=jnp.int32)
    newrun = jnp.concatenate(
        [jnp.ones((1,), jnp.bool_), skey[1:] != skey[:-1]])
    run_start = lax.associative_scan(jnp.maximum, jnp.where(newrun, i, 0))
    return order, skey, i - run_start, newrun


def _bucket_indices(coord: jax.Array, valid: jax.Array, n_buckets: int,
                    cap: int):
    """Mailbox scatter coordinates for one hop.

    Returns (order, row, col, fits, leftover_sorted, pos); ``row``/
    ``col`` address the ``(n_buckets, cap)`` mailbox grid in *sorted*
    order with out-of-range sentinels for rows that don't ship this hop.
    ``leftover_sorted`` marks valid messages beyond bucket capacity;
    ``pos`` is the within-bucket rank (telemetry reads bucket demand
    from it).
    """
    order, skey, pos, _ = sort_and_group(coord, valid, n_buckets)
    infit = skey < n_buckets
    fits = infit & (pos < cap)
    row = jnp.where(fits, skey, n_buckets).astype(jnp.int32)
    col = jnp.where(fits, pos, cap).astype(jnp.int32)
    return order, row, col, fits, infit & ~fits, pos


def _scatter_leaf(leaf_sorted: jax.Array, flat: jax.Array, n_rows: int):
    """Scatter sorted rows to flat mailbox slots (OOB slots dropped)."""
    buf = jnp.zeros((n_rows,) + leaf_sorted.shape[1:], leaf_sorted.dtype)
    return buf.at[flat].set(leaf_sorted, mode="drop")


# --------------------------------------------------------------------------
# routing
# --------------------------------------------------------------------------

def _check_payload(payload: dict[str, jax.Array], track_src: bool):
    for k in RESERVED_KEYS:
        if k in payload:
            raise ValueError(f"payload key {k!r} is reserved")
    if track_src and "src" in payload:
        raise ValueError("track_src=True would overwrite payload key 'src'")


def _route_impl(plan: MeshPlan, caps: Sequence[int],
                payload: dict[str, jax.Array], dest: jax.Array,
                valid: jax.Array, track_src: bool, queue_cap: int | None):
    """Shared body of :func:`route` and :func:`route_compact`.

    With ``queue_cap`` set, per-hop leftovers are compacted into a
    single queue *by the bucket sort itself* (prefix-sum slots over the
    sorted order — no extra sort); otherwise they are returned as the
    legacy per-hop fragment list.
    """
    hops = plan.indirection.hops
    assert len(caps) == len(hops)
    _check_payload(payload, track_src)
    user_keys = tuple(payload.keys())

    cur = dict(payload)
    cur["_dest"] = dest.astype(jnp.int32)
    cur_valid = valid
    src_acc = None
    leftovers = []
    if queue_cap is not None:
        lq = {k: jnp.zeros((queue_cap,) + v.shape[1:], v.dtype)
              for k, v in payload.items()}
        lq_dest = jnp.zeros(queue_cap, jnp.int32)
        nleft = jnp.int32(0)
    stats = {"sent": [], "leftover": jnp.int32(0)}
    tele_hops, tele_hist = [], None

    for h, (hop, cap) in enumerate(zip(hops, caps)):
        s = plan.hop_size(hop)
        q = cur_valid.shape[0]
        coord = plan.hop_coord(cur["_dest"], hop)
        order, row, col, fits, leftover_sorted, pos = _bucket_indices(
            coord, cur_valid, s, cap)
        flat = row * cap + col  # ≥ s*cap for non-shipping rows
        if plan.telemetry:
            # per-PE occupancy/skew sample of this hop: pure local
            # arithmetic on indices already computed — no collectives.
            infit = fits | leftover_sorted
            tele_hops.append({
                "demand_max": jnp.max(
                    jnp.where(infit, pos + 1, 0)).astype(jnp.int32),
                "delivered": jnp.sum(fits).astype(jnp.int32),
                "total": jnp.sum(infit).astype(jnp.int32),
                "cap": cap, "s": s,
            })
            if h == 0:
                bins = jnp.where(cur_valid,
                                 (coord * tele_lib.HIST_BINS) // max(s, 1),
                                 tele_lib.HIST_BINS)
                tele_hist = jnp.zeros(tele_lib.HIST_BINS, jnp.int32
                                      ).at[bins].add(1, mode="drop")
        # input-aligned mailbox slot: message i ships to slot io_flat[i]
        # (out of range => stays). One index scatter replaces a sorted
        # gather per payload leaf below.
        io_flat = jnp.full(q, s * cap + cap, jnp.int32).at[order].set(flat)

        nl = jnp.sum(leftover_sorted).astype(jnp.int32)
        if queue_cap is None:
            left_mask = jnp.zeros(q, jnp.bool_).at[order].set(leftover_sorted)
            leftovers.append(({k: cur[k] for k in user_keys},
                              cur["_dest"], cur_valid & left_mask))
        else:
            lpos = nleft + jnp.cumsum(leftover_sorted.astype(jnp.int32)) - 1
            lslot = jnp.where(leftover_sorted, lpos, queue_cap)
            io_lslot = jnp.full(q, queue_cap, jnp.int32).at[order].set(lslot)
            for k in lq:
                lq[k] = lq[k].at[io_lslot].set(cur[k], mode="drop")
            lq_dest = lq_dest.at[io_lslot].set(cur["_dest"], mode="drop")
            nleft = nleft + nl
        stats["sent"].append(jnp.sum(fits))
        stats["leftover"] = stats["leftover"] + nl

        # exchange: mailbox row b goes to the peer with coordinate b
        # along `hop`. The packed buffer is column-major (word-planes
        # first) so pack/unpack stay plane-contiguous; the collective
        # splits/concats the mailbox-row axis.
        if plan.wire_packing:
            wf = WireFormat.from_payload(cur)
            buf = _pack_scatter(plan, wf, cur, cur_valid, io_flat, s, cap)
            recv = plan.all_to_all(buf, hop, 1, 1)  # 1 collective
            cur, cur_valid = wf.unpack_cols(recv.reshape(wf.width, s * cap))
        else:
            recv = {}
            for k, v in cur.items():
                b = _scatter_leaf(v, io_flat, s * cap
                                  ).reshape((s, cap) + v.shape[1:])
                recv[k] = plan.all_to_all(b, hop, 0, 0)
            bval = _scatter_leaf(cur_valid, io_flat, s * cap).reshape(s, cap)
            rval = plan.all_to_all(bval, hop, 0, 0)
            cur = {k: v.reshape((s * cap,) + v.shape[2:])
                   for k, v in recv.items()}
            cur_valid = rval.reshape(s * cap)

        if track_src:
            # Sender reconstruction from the receive-buffer row index:
            # mailbox row b was filled by the peer whose coordinate
            # along `hop` is b (remaining axes match the receiver's
            # own), so accumulating the per-hop contributions over all
            # hops yields the full origin PE id — no 'src' leaf ever
            # leaves the origin. Valid only for messages that traverse
            # every hop in this call (leftovers are *not* re-routable
            # with track_src; remote_gather re-requests from origin).
            contrib = jnp.asarray(
                np.repeat(plan.hop_coord_to_pe(hop), cap), jnp.int32)
            prev = cur.pop("_src", None)
            src_acc = contrib if prev is None else prev + contrib
            if h < len(hops) - 1:
                cur["_src"] = src_acc

    if plan.telemetry:
        stats["telemetry"] = tele_lib.route_wave(tele_hops, tele_hist)
    delivered = {k: cur[k] for k in user_keys}
    if track_src:
        delivered["src"] = src_acc
    if queue_cap is not None:
        qv = jnp.arange(queue_cap, dtype=jnp.int32) < jnp.minimum(
            nleft, queue_cap)
        dropped = jnp.maximum(nleft - queue_cap, 0)
        return delivered, cur_valid, (lq, lq_dest, qv, dropped), stats
    return delivered, cur_valid, leftovers, stats


def _pack_scatter(plan: MeshPlan, wf: WireFormat, payload, valid,
                  io_flat, n_buckets: int, cap: int) -> jax.Array:
    """Pack + bucket-scatter into the (W, n_buckets, cap) send buffer."""
    from repro.kernels.mailbox_pack import ops as mp_ops
    cols = wf.columns(payload, valid)
    buf = mp_ops.mailbox_pack(cols, io_flat, n_buckets * cap,
                              use_pallas=plan.pallas_pack)
    return buf.reshape(wf.width, n_buckets, cap)


def route(plan: MeshPlan, caps: Sequence[int], payload: dict[str, jax.Array],
          dest: jax.Array, valid: jax.Array, track_src: bool = False):
    """Route messages to their destination PE through the plan's hops.

    Args:
      caps: per-peer mailbox capacity per hop (len == #hops).
      payload: dict of (Q, ...) arrays.
      dest: (Q,) destination PE ids (flattened over pe_axes).
      valid: (Q,) mask.
      track_src: reconstruct each message's origin PE from receive-
        buffer row indices (see :func:`_route_impl`); the result is
        returned as ``delivered["src"]`` without shipping a source leaf.

    Returns:
      delivered: dict of (R, ...) arrays (R = hop_size[-1] * caps[-1]),
      delivered_valid: (R,),
      leftovers: list of (payload_dict, dest, valid) per hop — messages
        stuck on this PE awaiting the next round,
      stats: dict with per-hop sent counts and total leftover count.
    """
    return _route_impl(plan, caps, payload, dest, valid, track_src,
                       queue_cap=None)


def route_compact(plan: MeshPlan, caps: Sequence[int],
                  frags: Sequence[tuple[dict[str, jax.Array], jax.Array,
                                        jax.Array]],
                  queue_cap: int):
    """Route concatenated fragments; leftovers come back as one compact
    queue. The first-hop bucket sort *is* the queue compaction — a chase
    round costs a single stable sort per hop, with no separate
    ``compact_queue`` pass.

    Returns (delivered, delivered_valid, (queue_payload, queue_dest,
    queue_valid), dropped, stats).
    """
    payload, dest, valid = _concat_frags(frags)
    delivered, dval, (qpl, qd, qv, dropped), stats = _route_impl(
        plan, caps, payload, dest, valid, track_src=False,
        queue_cap=queue_cap)
    return delivered, dval, (qpl, qd, qv), dropped, stats


def _concat_frags(entries):
    keys = tuple(entries[0][0].keys())
    for pl, _, _ in entries:
        if tuple(pl.keys()) != keys and set(pl.keys()) != set(keys):
            raise ValueError("fragments must share payload keys")
    payload = {k: jnp.concatenate([pl[k] for pl, _, _ in entries], axis=0)
               for k in keys}
    dest = jnp.concatenate([d for _, d, _ in entries], axis=0)
    valid = jnp.concatenate([v for _, _, v in entries], axis=0)
    return payload, dest, valid


def compact_queue(entries: Sequence[tuple[dict[str, jax.Array], jax.Array,
                                          jax.Array]],
                  cap: int):
    """Merge (payload, dest, valid) fragments into one queue of size cap.

    Valid entries are packed to the front *in order* by a prefix-sum
    scatter — O(Q), no sort. Returns (payload, dest, valid,
    dropped_count) — dropped_count > 0 means ``cap`` was too small and
    the run must be retried with larger capacities.
    """
    cat_payload, cat_dest, cat_valid = _concat_frags(entries)
    pos = jnp.cumsum(cat_valid.astype(jnp.int32)) - 1
    slot = jnp.where(cat_valid, pos, cap)  # cap => dropped by mode="drop"
    out_payload = {
        k: jnp.zeros((cap,) + v.shape[1:], v.dtype).at[slot].set(
            v, mode="drop")
        for k, v in cat_payload.items()}
    out_dest = jnp.zeros(cap, cat_dest.dtype).at[slot].set(
        cat_dest, mode="drop")
    n_valid = jnp.sum(cat_valid).astype(jnp.int32)
    out_valid = jnp.arange(cap, dtype=jnp.int32) < jnp.minimum(n_valid, cap)
    dropped = jnp.maximum(n_valid - cap, 0)
    return out_payload, out_dest, out_valid, dropped


# --------------------------------------------------------------------------
# request/response gather
# --------------------------------------------------------------------------

def request_reply(plan: MeshPlan, req_caps, resp_caps,
                  payload: dict[str, jax.Array], dest: jax.Array,
                  valid: jax.Array, reply_fn):
    """Two-leg owner-computes exchange (request round + reply round).

    Route ``payload`` to ``dest``; on the receiving PE, ``reply_fn``
    turns the delivered batch into a reply batch with its *own*
    addressing; route those replies and return them. This is the shared
    shape of ``treealg.euler``'s report/reply tour construction and
    ``graphalg``'s adjacency-linking round — unlike
    :func:`remote_gather` (keyed fetch by target id, origin
    reconstructed from receive rows), the owner computes both the reply
    content and the reply destinations, typically after regrouping the
    requests with :func:`sort_and_group`.

    Args:
      req_caps / resp_caps: per-hop mailbox capacities for the two legs
        (int => replicated over hops).
      reply_fn: (delivered_payload, delivered_valid) ->
        (reply_payload, reply_dest, reply_valid[, aux]); ``aux`` is any
        pytree of side outputs the owner derives while grouping (e.g.
        per-local-node marks) and is returned through untouched.

    Returns:
      (reply_delivered, reply_valid, aux, stats) with ``aux`` None when
      ``reply_fn`` returns a 3-tuple and ``stats = {"sent", "leftover"}``
      summed over both legs (a nonzero leftover means a capacity
      overflow somewhere; the caller must retry with larger caps).
    """
    def as_caps(c):
        return list(c) if isinstance(c, (tuple, list)) \
            else [c] * plan.indirection.depth

    delivered, dval, _, st1 = route(plan, as_caps(req_caps), payload, dest,
                                    valid)
    out = reply_fn(delivered, dval)
    rpl, rdest, rvalid = out[:3]
    aux = out[3] if len(out) > 3 else None
    rdel, rval, _, st2 = route(plan, as_caps(resp_caps), rpl,
                               rdest.astype(jnp.int32), rvalid)
    stats = {"sent": sum(st1["sent"] + st2["sent"]).astype(jnp.int32),
             "leftover": st1["leftover"] + st2["leftover"]}
    if plan.telemetry:
        stats["telemetry"] = tele_lib.merge(st1["telemetry"],
                                            st2["telemetry"])
    return rdel, rval, aux, stats


def remote_gather(plan: MeshPlan, targets: jax.Array, valid: jax.Array,
                  owner_of: Callable[[jax.Array], jax.Array],
                  lookup_fn: Callable[[jax.Array, jax.Array], dict[str, jax.Array]],
                  req_cap, resp_cap, dedup: bool = True):
    """Fetch per-element data about remote ``targets`` (request/response).

    The paper's ruler-propagation and §2.5 postprocessing both reduce to
    this primitive; ``dedup=True`` implements the paper's per-PE request
    aggregation (identical targets are asked once, then fanned back out).
    Requests carry no source-PE leaf: the responder rebuilds the origin
    from receive-buffer row indices (``route(track_src=True)``).

    Args:
      targets: (Q,) global element ids to query.
      valid: (Q,) mask.
      owner_of: global id -> owning PE id.
      lookup_fn: (ids (R,), valid (R,)) -> dict of (R, ...) response
        leaves, evaluated on the owning PE.
      req_cap/resp_cap: per-peer mailbox capacity for the two legs.

    Returns:
      values: dict of (Q, ...) arrays aligned with ``targets``,
      answered: (Q,) mask of queries answered (False => capacity
        overflow somewhere; caller must retry with larger caps),
      stats: message-count stats.
    """
    q = targets.shape[0]
    if dedup:
        order, skey, _, newrun = sort_and_group(targets, valid, INT_MAX)
        is_uniq = newrun & (skey != INT_MAX)
        group = jnp.cumsum(is_uniq.astype(jnp.int32)) - 1
        uniq_slot = jnp.where(is_uniq, group, q)
        req_targets = jnp.zeros(q, targets.dtype).at[uniq_slot].set(
            skey, mode="drop")
        n_uniq = jnp.sum(is_uniq).astype(jnp.int32)
        req_valid = jnp.arange(q, dtype=jnp.int32) < n_uniq
        # original slot i -> unique slot group[rank of i in sort]
        inv = jnp.zeros(q, jnp.int32).at[order].set(group)
    else:
        req_targets, req_valid = targets, valid
        inv = jnp.arange(q, dtype=jnp.int32)

    payload = {
        "target": req_targets,
        "slot": jnp.arange(q, dtype=jnp.int32),
    }
    dest = owner_of(req_targets).astype(jnp.int32)
    caps_req = list(req_cap) if isinstance(req_cap, (tuple, list)) \
        else [req_cap] * plan.indirection.depth
    delivered, dval, leftovers, st_req = route(plan, caps_req, payload, dest,
                                               req_valid, track_src=True)
    req_left = sum(jnp.sum(lv).astype(jnp.int32) for _, _, lv in leftovers)

    # answer on the owner
    values = lookup_fn(delivered["target"], dval)
    resp_payload = dict(values)
    resp_payload["slot"] = delivered["slot"]
    resp_dest = delivered["src"]
    caps_resp = list(resp_cap) if isinstance(resp_cap, (tuple, list)) \
        else [resp_cap] * plan.indirection.depth
    rdel, rval, rleft, st_resp = route(plan, caps_resp, resp_payload,
                                       resp_dest, dval)
    resp_left = sum(jnp.sum(lv).astype(jnp.int32) for _, _, lv in rleft)

    # scatter responses into the unique-request table
    slot = jnp.where(rval, rdel["slot"], q).astype(jnp.int32)
    uniq_values = {}
    uniq_answered = jnp.zeros(q + 1, jnp.bool_).at[slot].set(
        rval, mode="drop")[:q]
    for k in values:
        leaf = rdel[k]
        buf = jnp.zeros((q + 1,) + leaf.shape[1:], leaf.dtype
                        ).at[slot].set(leaf, mode="drop")
        uniq_values[k] = buf[:q]
    out = {k: v[inv] for k, v in uniq_values.items()}
    answered = uniq_answered[inv] & valid
    stats = {
        "req_sent": sum(st_req["sent"]),
        "resp_sent": sum(st_resp["sent"]),
        "undelivered": req_left + resp_left,
    }
    if plan.telemetry:
        stats["telemetry"] = tele_lib.merge(st_req["telemetry"],
                                            st_resp["telemetry"])
    return out, answered, stats
