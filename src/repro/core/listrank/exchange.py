"""Bucketed message routing over a JAX device mesh (paper §2.4).

The paper sends sparse point-to-point messages over MPI; on a TPU mesh
we realize each communication round as one (or ``d``, with indirection)
dense, fixed-capacity ``all_to_all`` per hop. A *hop* fixes the
destination coordinate along one mesh-axis group. Direct delivery is a
single hop over all PE axes; grid indirection is one hop per axis
(minor axis first — the paper's column-then-row routing); topology-aware
indirection hops over the intra-node axis first.

Static shapes force a per-peer mailbox capacity. Messages that do not
fit are *leftovers*: they stay on the holding PE and re-enter routing in
the caller's next round (re-routing from an intermediate PE is correct
because every hop fixes its own coordinate, so partially-routed messages
simply self-send on already-fixed hops). Capacity overflow therefore
costs rounds, never correctness; the amount is tracked in ``stats``.

All functions here run *inside* ``jax.shard_map`` — per-PE arrays,
collectives by axis name.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.listrank.config import IndirectionSpec

Pytree = Any


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """Static routing metadata for a PE grid embedded in a mesh.

    PE ids are flattened row-major over ``pe_axes`` (matching
    ``lax.axis_index(pe_axes)``).
    """

    pe_axes: tuple[str, ...]
    axis_sizes: tuple[int, ...]
    indirection: IndirectionSpec

    @property
    def p(self) -> int:
        out = 1
        for s in self.axis_sizes:
            out *= s
        return out

    def axis_size(self, name: str) -> int:
        return self.axis_sizes[self.pe_axes.index(name)]

    def hop_size(self, hop: tuple[str, ...]) -> int:
        out = 1
        for a in hop:
            out *= self.axis_size(a)
        return out

    def my_id(self) -> jax.Array:
        return lax.axis_index(self.pe_axes)

    def hop_coord(self, pe_id: jax.Array, hop: tuple[str, ...]) -> jax.Array:
        """Coordinate of ``pe_id`` along the (possibly non-contiguous)
        axis group ``hop``, flattened row-major within the group."""
        coord = jnp.zeros_like(pe_id)
        for a in hop:
            i = self.pe_axes.index(a)
            stride = 1
            for s in self.axis_sizes[i + 1:]:
                stride *= s
            c = (pe_id // stride) % self.axis_sizes[i]
            coord = coord * self.axis_sizes[i] + c
        return coord

    @staticmethod
    def from_mesh(mesh: jax.sharding.Mesh, pe_axes: Sequence[str],
                  indirection: IndirectionSpec | None = None) -> "MeshPlan":
        pe_axes = tuple(pe_axes)
        sizes = tuple(mesh.shape[a] for a in pe_axes)
        if indirection is None:
            indirection = IndirectionSpec.direct(pe_axes)
        for hop in indirection.hops:
            for a in hop:
                if a not in pe_axes:
                    raise ValueError(f"hop axis {a} not in pe_axes {pe_axes}")
        return MeshPlan(pe_axes=pe_axes, axis_sizes=sizes, indirection=indirection)


def _bucket(payload: dict[str, jax.Array], coord: jax.Array, valid: jax.Array,
            n_buckets: int, cap: int):
    """Scatter messages into per-destination-coordinate mailboxes.

    Returns (buffers, buf_valid, leftover_mask). ``buffers[k]`` has shape
    (n_buckets, cap) + leaf shape; row b holds the first ``cap`` valid
    messages whose coord == b. Messages beyond capacity keep their slot
    in the input (leftover_mask True).
    """
    q = coord.shape[0]
    key = jnp.where(valid, coord, n_buckets)
    order = jnp.argsort(key, stable=True)
    skey = key[order]
    # start offset of each bucket in the sorted order
    starts = jnp.searchsorted(skey, jnp.arange(n_buckets + 1, dtype=skey.dtype))
    pos = jnp.arange(q, dtype=jnp.int32) - starts[jnp.minimum(skey, n_buckets)].astype(jnp.int32)
    fits = (skey < n_buckets) & (pos < cap)
    row = jnp.where(fits, skey, n_buckets).astype(jnp.int32)
    col = jnp.where(fits, pos, cap).astype(jnp.int32)

    def scatter(leaf):
        sleaf = leaf[order]
        buf = jnp.zeros((n_buckets + 1, cap + 1) + leaf.shape[1:], leaf.dtype)
        buf = buf.at[row, col].set(sleaf, mode="drop")
        return buf[:n_buckets, :cap]

    buffers = {k: scatter(v) for k, v in payload.items()}
    bval = jnp.zeros((n_buckets + 1, cap + 1), jnp.bool_).at[row, col].set(fits, mode="drop")
    leftover_sorted = jnp.where(skey < n_buckets, ~fits, False)
    leftover = jnp.zeros(q, jnp.bool_).at[order].set(leftover_sorted)
    return buffers, bval[:n_buckets, :cap], leftover


def route(plan: MeshPlan, caps: Sequence[int], payload: dict[str, jax.Array],
          dest: jax.Array, valid: jax.Array):
    """Route messages to their destination PE through the plan's hops.

    Args:
      caps: per-peer mailbox capacity per hop (len == #hops).
      payload: dict of (Q, ...) arrays.
      dest: (Q,) destination PE ids (flattened over pe_axes).
      valid: (Q,) mask.

    Returns:
      delivered: dict of (R, ...) arrays (R = hop_size[-1] * caps[-1]),
      delivered_valid: (R,),
      leftovers: list of (payload_dict, dest, valid) per hop — messages
        stuck on this PE awaiting the next round,
      stats: dict with per-hop sent counts and total leftover count.
    """
    hops = plan.indirection.hops
    assert len(caps) == len(hops)
    cur_payload = dict(payload)
    cur_payload["_dest"] = dest
    cur_valid = valid
    leftovers = []
    stats = {"sent": [], "leftover": jnp.int32(0)}
    for hop, cap in zip(hops, caps):
        s = plan.hop_size(hop)
        coord = plan.hop_coord(cur_payload["_dest"], hop)
        buffers, bval, left = _bucket(cur_payload, coord, cur_valid, s, cap)
        left_payload = {k: v for k, v in cur_payload.items() if k != "_dest"}
        leftovers.append((left_payload,
                          cur_payload["_dest"],
                          cur_valid & left))
        stats["sent"].append(jnp.sum(bval))
        stats["leftover"] = stats["leftover"] + jnp.sum(cur_valid & left).astype(jnp.int32)
        # exchange: row b goes to peer with coordinate b along `hop`
        recv = {k: lax.all_to_all(v, hop, 0, 0, tiled=True) for k, v in buffers.items()}
        rval = lax.all_to_all(bval, hop, 0, 0, tiled=True)
        cur_payload = {k: v.reshape((s * cap,) + v.shape[2:]) for k, v in recv.items()}
        cur_valid = rval.reshape(s * cap)
    delivered = {k: v for k, v in cur_payload.items() if k != "_dest"}
    return delivered, cur_valid, leftovers, stats


def compact_queue(entries: Sequence[tuple[dict[str, jax.Array], jax.Array, jax.Array]],
                  cap: int):
    """Merge (payload, dest, valid) fragments into one queue of size cap.

    Valid entries are packed to the front. Returns (payload, dest, valid,
    dropped_count) — dropped_count > 0 means ``cap`` was too small and
    the run must be retried with larger capacities.
    """
    keys = set()
    for pl, _, _ in entries:
        keys |= set(pl.keys())
    cat_payload = {}
    for k in keys:
        cat_payload[k] = jnp.concatenate([pl[k] for pl, _, _ in entries], axis=0)
    cat_dest = jnp.concatenate([d for _, d, _ in entries], axis=0)
    cat_valid = jnp.concatenate([v for _, _, v in entries], axis=0)
    total = cat_valid.shape[0]
    if total < cap:  # pad up to capacity (small instances / levels)
        pad = cap - total
        cat_payload = {k: jnp.concatenate(
            [v, jnp.zeros((pad,) + v.shape[1:], v.dtype)])
            for k, v in cat_payload.items()}
        cat_dest = jnp.concatenate([cat_dest, jnp.zeros(pad, cat_dest.dtype)])
        cat_valid = jnp.concatenate([cat_valid, jnp.zeros(pad, jnp.bool_)])
    order = jnp.argsort(~cat_valid, stable=True)  # valid first
    n_valid = jnp.sum(cat_valid).astype(jnp.int32)
    take = order[:cap]
    out_payload = {k: v[take] for k, v in cat_payload.items()}
    out_dest = cat_dest[take]
    out_valid = jnp.arange(cap, dtype=jnp.int32) < jnp.minimum(n_valid, cap)
    dropped = jnp.maximum(n_valid - cap, 0)
    return out_payload, out_dest, out_valid, dropped


def remote_gather(plan: MeshPlan, targets: jax.Array, valid: jax.Array,
                  owner_of: Callable[[jax.Array], jax.Array],
                  lookup_fn: Callable[[jax.Array, jax.Array], dict[str, jax.Array]],
                  req_cap, resp_cap, dedup: bool = True):
    """Fetch per-element data about remote ``targets`` (request/response).

    The paper's ruler-propagation and §2.5 postprocessing both reduce to
    this primitive; ``dedup=True`` implements the paper's per-PE request
    aggregation (identical targets are asked once, then fanned back out).

    Args:
      targets: (Q,) global element ids to query.
      valid: (Q,) mask.
      owner_of: global id -> owning PE id.
      lookup_fn: (ids (R,), valid (R,)) -> dict of (R, ...) response
        leaves, evaluated on the owning PE.
      req_cap/resp_cap: per-peer mailbox capacity for the two legs.

    Returns:
      values: dict of (Q, ...) arrays aligned with ``targets``,
      answered: (Q,) mask of queries answered (False => capacity
        overflow somewhere; caller must retry with larger caps),
      stats: message-count stats.
    """
    q = targets.shape[0]
    if dedup:
        key = jnp.where(valid, targets, jnp.iinfo(targets.dtype).max)
        order = jnp.argsort(key)
        skey = key[order]
        is_uniq = jnp.concatenate([jnp.ones(1, jnp.bool_), skey[1:] != skey[:-1]])
        is_uniq = is_uniq & (skey != jnp.iinfo(targets.dtype).max)
        group = jnp.cumsum(is_uniq) - 1  # sorted-slot -> unique-slot
        uniq_slot = jnp.where(is_uniq, group, q - 1).astype(jnp.int32)
        req_targets = jnp.zeros(q, targets.dtype).at[uniq_slot].set(
            jnp.where(is_uniq, skey, 0), mode="drop")
        n_uniq = jnp.sum(is_uniq).astype(jnp.int32)
        req_valid = jnp.arange(q, dtype=jnp.int32) < n_uniq
        # original slot i -> unique slot group[rank of i in sort]
        inv = jnp.zeros(q, jnp.int32).at[order].set(group.astype(jnp.int32))
    else:
        req_targets, req_valid, inv = targets, valid, jnp.arange(q, dtype=jnp.int32)

    me = plan.my_id().astype(jnp.int32)
    payload = {
        "target": req_targets,
        "slot": jnp.arange(q, dtype=jnp.int32),
        "src": jnp.full((q,), 0, jnp.int32) + me,
    }
    dest = owner_of(req_targets).astype(jnp.int32)
    caps_req = list(req_cap) if isinstance(req_cap, (tuple, list)) \
        else [req_cap] * plan.indirection.depth
    delivered, dval, leftovers, st_req = route(plan, caps_req, payload, dest, req_valid)
    req_left = sum(jnp.sum(lv).astype(jnp.int32) for _, _, lv in leftovers)

    # answer on the owner
    values = lookup_fn(delivered["target"], dval)
    resp_payload = dict(values)
    resp_payload["slot"] = delivered["slot"]
    resp_dest = delivered["src"]
    caps_resp = list(resp_cap) if isinstance(resp_cap, (tuple, list)) \
        else [resp_cap] * plan.indirection.depth
    rdel, rval, rleft, st_resp = route(plan, caps_resp, resp_payload, resp_dest, dval)
    resp_left = sum(jnp.sum(lv).astype(jnp.int32) for _, _, lv in rleft)

    # scatter responses into the unique-request table
    slot = jnp.where(rval, rdel["slot"], q).astype(jnp.int32)
    uniq_values = {}
    uniq_answered = jnp.zeros(q + 1, jnp.bool_).at[slot].set(rval, mode="drop")[:q]
    for k in values:
        leaf = rdel[k]
        buf = jnp.zeros((q + 1,) + leaf.shape[1:], leaf.dtype).at[slot].set(leaf, mode="drop")
        uniq_values[k] = buf[:q]
    out = {k: v[inv] for k, v in uniq_values.items()}
    answered = uniq_answered[inv] & valid
    stats = {
        "req_sent": sum(st_req["sent"]),
        "resp_sent": sum(st_resp["sent"]),
        "undelivered": req_left + resp_left,
    }
    return out, answered, stats
