"""Local sublist contraction (paper §2.3) and its restoration.

Runs entirely PE-locally (no communication). The paper chases local
chains sequentially in O(n/p); a TPU has no fast scalar loop over HBM,
so we *vectorize* the chase as pointer doubling restricted to local
links: O((n/p)·log(chain)) vector work on the VPU — the hardware
adaptation discussed in DESIGN.md. The doubling inner loop can run as a
Pallas VMEM kernel (``repro.kernels.local_chase``) via ``use_pallas``.

Definitions (per PE with local index range [0, m), global base b):
  stop element: local element whose successor is non-local or itself
  S[i]: local index of the stop element ending i's local chain
  D[i]: weighted distance from i to S[i] (sum of weights of links
        i -> ... -> S[i], excluding S[i]'s own outgoing link)
  rep:  local elements with no local predecessor (local-initial) —
        the contracted instance consists exactly of the reps.

Contracted instance (only reps active):
  succ_c[l] = succ[S[l]]  (remote, or l itself if S[l] is terminal)
  rank_c[l] = D[l] + rank[S[l]]  (0 if S[l] is terminal)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _doubling(succ_l: jax.Array, dist: jax.Array, steps: int, use_pallas: bool):
    """Wyllie iterations over local links with self-absorbing stops."""
    if use_pallas:
        from repro.kernels.local_chase import ops as lc_ops
        return lc_ops.local_chase(succ_l, dist, steps)

    def body(_, sd):
        s, d = sd
        return s[s], d + d[s]

    return jax.lax.fori_loop(0, steps, body, (succ_l, dist))


def contract(succ: jax.Array, rank: jax.Array, base: jax.Array, m: int,
             use_pallas: bool = False):
    """Contract local sublists. Returns (succ_c, rank_c, rep, aux) where
    aux = dict(S, D, stop_is_term) is needed by :func:`restore_local`."""
    lidx = jnp.arange(m, dtype=jnp.int32)
    gid = base + lidx
    is_term = succ == gid
    succ_local = succ - base
    is_local = (succ_local >= 0) & (succ_local < m)
    stop = (~is_local) | is_term

    succ_l = jnp.where(stop, lidx, jnp.clip(succ_local, 0, m - 1).astype(jnp.int32))
    dist0 = jnp.where(stop, jnp.zeros_like(rank), rank)
    steps = max(1, (m - 1).bit_length())
    S, D = _doubling(succ_l, dist0, steps, use_pallas)

    # rep = no local predecessor (self-loops don't count as local preds)
    has_local_pred = jnp.zeros(m + 1, jnp.bool_).at[
        jnp.where(is_local & ~is_term, succ_local, m)
    ].set(True, mode="drop")[:m]
    rep = ~has_local_pred

    stop_is_term = is_term[S]
    succ_c = jnp.where(stop_is_term, gid, succ[S])
    rank_c = jnp.where(stop_is_term, jnp.zeros_like(rank), D + rank[S])
    # non-reps are parked as inert self-loops; the `rep` mask excludes
    # them from the distributed instance entirely.
    succ_c = jnp.where(rep, succ_c, gid)
    rank_c = jnp.where(rep, rank_c, jnp.zeros_like(rank_c))
    aux = dict(S=S, D=D, stop_is_term=stop_is_term)
    return succ_c, rank_c, rep, aux


def tail_lookup(aux, succ_orig, rank_orig, base):
    """Owner-side data for restore: for a queried element x (a rep whose
    chain ends at a true terminal), return (terminal gid, distance)."""
    def fn(gids: jax.Array, valid: jax.Array):
        m = aux["S"].shape[0]
        slot = jnp.clip(gids - base, 0, m - 1).astype(jnp.int32)
        ok = valid & (gids >= base) & (gids < base + m)
        t_gid = base + aux["S"][slot]
        return {
            "succ": jnp.where(ok, t_gid, gids),
            "rank": jnp.where(ok, aux["D"][slot], jnp.zeros_like(aux["D"][slot])),
            "found": ok,
        }
    return fn
