"""Host-side parameter engine: the §2.6 cost model drives the solver.

The paper's engineering contribution beyond Sibeyn's algorithm is the
detailed parameter analysis (Observation 1 / Corollary 1) used to pick
the ruler count r, the indirection depth d, and the capacities. This
module turns :mod:`repro.core.listrank.analysis` into the single source
of truth for those choices:

- :func:`level_plan` — per-recursion-level ruler fractions. With
  ``ListRankConfig.ruler_fraction=None`` each level's r comes from
  ``analysis.r_star`` applied to the *expected* instance size entering
  that level (``analysis.expected_subproblem`` shrinks it level by
  level); a fixed fraction is passed through unchanged. ``api.build_specs``
  sizes every capacity from this plan, and the fraction is carried into
  ``LevelSpec.ruler_frac`` so the in-program ruler target in
  ``srs.solve_store`` shares the exact same derivation (the dynamic
  ``r_target`` can therefore never exceed the static ``r_static``).

- :func:`choose_indirection` / :func:`choose_algorithm` — cost-model
  selection of the routing scheme (direct vs grid vs topology-aware,
  via :func:`analysis.t_hops` with intra-node constants for the
  topology hop) and the Corollary-1 regime check that falls back to
  plain pointer doubling when n/p is below
  ``analysis.efficiency_threshold``.

- :class:`CapacityScales` / :func:`escalate` — **targeted** capacity
  retries. Each fatal stat names the capacity family that overflowed
  (``dropped`` → chase mail/queue, ``sub_overflow`` → the recursion
  sub-store, ``undelivered`` → gather request/response); a retry
  doubles only that family instead of every capacity, bounding both the
  memory blowup and the number of recompiles.

Everything here is host-side python on static quantities — nothing is
traced.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core.listrank import analysis
from repro.core.listrank.config import IndirectionSpec, ListRankConfig

#: hard cap on the per-level ruler fraction: r*/n can exceed 1 for
#: small instances (r* is an asymptotic optimum); capping at 1/4 keeps
#: the expected subproblem r·ln(n/r) strictly shrinking (factor ≈ 0.35).
RULER_FRAC_CAP = 0.25


@dataclasses.dataclass(frozen=True)
class LevelParams:
    """Cost-model output for one recursion level (host-side)."""
    frac: float        #: ruler fraction of the live instance
    n_expected: int    #: expected global instance size entering the level
    r_total: int       #: modeled global ruler count


def level_plan(cfg: ListRankConfig, p: int, d: int,
               n: int) -> tuple[LevelParams, ...]:
    """Per-level ruler fractions for ``srs_rounds`` levels.

    The single shared derivation behind both ``api.build_specs``
    (capacity sizing) and ``srs.solve_store`` (the runtime ruler
    target, via ``LevelSpec.ruler_frac``).
    """
    out: list[LevelParams] = []
    n_level = max(int(n), 1)
    for _ in range(cfg.srs_rounds):
        if cfg.ruler_fraction is not None:
            # fixed fraction: passed through exactly (legacy behavior)
            frac = min(cfg.ruler_fraction, 1.0)
            r_tot = max(int(math.ceil(frac * n_level)), 1)
        else:
            floor_r = max(cfg.min_rulers_per_pe * p, 1)
            cap_r = max(int(math.ceil(RULER_FRAC_CAP * n_level)), 1)
            r_tot = analysis.r_star(n_level, p, d, cfg.machine)
            r_tot = min(max(r_tot, floor_r), max(cap_r, floor_r))
            frac = min(r_tot / n_level, 1.0)
        out.append(LevelParams(frac=frac, n_expected=n_level, r_total=r_tot))
        n_level = max(int(math.ceil(
            analysis.expected_subproblem(n_level, min(r_tot, n_level)))), 1)
    return tuple(out)


# --------------------------------------------------------------------------
# indirection / algorithm selection
# --------------------------------------------------------------------------

def _hop_models(cfg: ListRankConfig, spec: IndirectionSpec,
                intra_hop: tuple[str, ...] | None):
    """Machine model per hop: intra-node constants for the designated
    intra-node hop of a topology-aware spec, ``cfg.machine`` otherwise."""
    return tuple(analysis.INTRA_NODE if hop == intra_hop else cfg.machine
                 for hop in spec.hops)


def candidate_indirections(pe_axes: Sequence[str], axis_sizes: Sequence[int]):
    """The routing schemes the mesh shape admits, as
    ``(name, spec, intra_hop)`` triples. Size-1 axes are excluded from
    grid/topology hops — a hop over a one-PE group is a real collective
    that moves nothing (coordinate 0 needs no fixing). Topology-aware
    treats the minor (fastest-varying) non-trivial axis as intra-node,
    matching how production meshes map PEs onto pod factors
    (launch/mesh.py)."""
    pe_axes = tuple(pe_axes)
    cands = [("direct", IndirectionSpec.direct(pe_axes), None)]
    multi = tuple(a for a, s in zip(pe_axes, axis_sizes) if s > 1)
    if len(multi) > 1:
        grid = IndirectionSpec(hops=tuple((a,) for a in reversed(multi)))
        cands.append(("grid", grid, None))
        intra, inter = (multi[-1],), tuple(multi[:-1])
        cands.append(("topology",
                      IndirectionSpec.topology(intra, inter), intra))
    return cands


def choose_indirection(cfg: ListRankConfig, pe_axes: Sequence[str],
                       axis_sizes: Sequence[int], n: int) -> IndirectionSpec:
    """Pick the indirection scheme with the lowest modeled time.

    Each candidate is scored with its own r* (deeper indirection shifts
    the alpha/beta balance, so the optimal r moves with it)."""
    p = math.prod(axis_sizes)
    best, best_t = None, float("inf")
    for _, spec, intra_hop in candidate_indirections(pe_axes, axis_sizes):
        hop_sizes = tuple(
            math.prod(axis_sizes[list(pe_axes).index(a)] for a in hop)
            for hop in spec.hops)
        models = _hop_models(cfg, spec, intra_hop)
        r = analysis.r_star(n, p, spec.depth, cfg.machine)
        t = analysis.t_hops(n, p, r, hop_sizes, models)
        if t < best_t:
            best, best_t = spec, t
    return best


def choose_algorithm(cfg: ListRankConfig, p: int, d: int, m: int) -> str:
    """Resolve ``algorithm="auto"``: SRS in the Corollary-1 efficient
    regime, plain pointer doubling below it (n/p too small for the
    chase's alpha terms to amortize)."""
    if cfg.algorithm != "auto":
        return cfg.algorithm
    thr = analysis.efficiency_threshold(p, d, cfg.machine)
    return "doubling" if m < thr else "srs"


# --------------------------------------------------------------------------
# targeted capacity retries
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CapacityScales:
    """Per-family capacity multipliers for the retry loop.

    ``chase`` scales the chase-phase mailbox and queue capacities,
    ``sub`` the recursion sub-store, ``gather`` the remote-gather
    request/response mailboxes, ``graph`` the graphalg hooking-round
    capacities (label/jump gathers, hook proposals and confirmations,
    adjacency reports, and the hooking-round budget itself — see
    ``graphalg.cc.GraphCaps.scaled``). All 1.0 on the first attempt.
    """
    chase: float = 1.0
    sub: float = 1.0
    gather: float = 1.0
    graph: float = 1.0


def format_scales(scales: CapacityScales) -> str:
    """Canonical one-line rendering of a scale vector — the golden
    bit-identity pins compare the per-attempt escalation path as text."""
    return ",".join(f"{f.name}={getattr(scales, f.name):g}"
                    for f in dataclasses.fields(scales))


#: fatal stat -> the capacity families whose overflow it signals.
#: ``store_miss`` has no capacity interpretation (it indicates routing
#: to the wrong owner), so it conservatively rescales everything.
#: The ``cc_*``/``tour_*``/``stats_*`` keys are the graphalg hooking
#: pipeline's overflow stats: destinations there follow the *dynamic*
#: label structure (hotspots concentrate on small labels), so their
#: caps are slack-based rather than host-exact and re-double under the
#: dedicated ``graph`` family; ``cc_unconverged`` additionally doubles
#: the hooking-round budget through the same scale.
FAMILY_OF = {
    "dropped": ("chase",),
    "sub_overflow": ("sub",),
    "undelivered": ("gather",),
    "store_miss": ("chase", "sub", "gather"),
    "cc_undelivered": ("graph",),
    "cc_unconverged": ("graph",),
    "tour_undelivered": ("graph",),
    "stats_undelivered": ("graph",),
}

_ALL_FAMILIES = ("chase", "sub", "gather", "graph")

#: stats that are NOT capacity-exclusive: ``undelivered`` also captures
#: chase coverage failures (restart-loop stragglers) and chase-mailbox
#: ``route_until_done`` pendings, which no amount of gather capacity
#: fixes. The exclusive stats (dropped, sub_overflow) always make
#: progress by re-doubling their own family.
AMBIGUOUS_STATS = ("undelivered",)


def normalize_level_scales(scales, n_levels: int) -> tuple[CapacityScales, ...]:
    """Broadcast a single :class:`CapacityScales` (or pass through a
    sequence) to one entry per recursion level (``srs_rounds`` chase
    levels + the base level). Per-level scales are what makes
    level-resume sound: escalating level k must not change the static
    shapes of the already-checkpointed levels < k."""
    if isinstance(scales, CapacityScales):
        return (scales,) * n_levels
    scales = tuple(scales)
    if len(scales) != n_levels:
        raise ValueError(
            f"expected {n_levels} per-level scales, got {len(scales)}")
    return scales


def escalate_levels(level_scales: Sequence[CapacityScales], level: int,
                    stats: dict, factor: float = 2.0
                    ) -> tuple[CapacityScales, ...]:
    """Level-resume escalation: rescale the implicated families at the
    faulting level and every level below it in the recursion (>= level),
    leaving completed levels' scales — and therefore their checkpointed
    store shapes — untouched."""
    level = max(level, 0)
    return tuple(escalate(s, stats, factor) if k >= level else s
                 for k, s in enumerate(level_scales))


def escalate(scales: CapacityScales, stats: dict,
             factor: float = 2.0) -> CapacityScales:
    """Rescale only the capacity families implicated by the fatal stats
    in ``stats`` (global rescale if none of the known keys fired).

    Widening ladder for the ambiguous stats only: when an
    ``AMBIGUOUS_STATS`` key persists after its own family was already
    rescaled, its mapping was evidently not the bottleneck, so that
    retry widens to a global rescale. Capacity-exclusive stats keep
    re-doubling their own family however often they fire — targeting
    is never permanently degraded."""
    bump = set()
    widen = False
    for key, fams in FAMILY_OF.items():
        if stats.get(key, 0) > 0:
            bump.update(fams)
            if key in AMBIGUOUS_STATS and \
                    all(getattr(scales, f) > 1.0 for f in fams):
                widen = True
    if not bump or widen:
        bump = set(_ALL_FAMILIES)
    return dataclasses.replace(
        scales, **{f: getattr(scales, f) * factor for f in bump})


# --------------------------------------------------------------------------
# sampled-splitter capacity estimation
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CapacityEstimate:
    """Measured per-hop destination skew, replacing the static slack
    guess (Robust-Massively-Parallel-Sorting-style splitter sampling).

    ``hop_slack[i]`` is the effective capacity-slack multiplier for hop
    i of the indirection: expected hottest-bucket load over the uniform
    load, plus a DKW sampling margin and an oversampling guard. With a
    uniform instance it collapses to ~``guard``; a skewed instance
    (hotspot owners) raises exactly the hops that will see the skew.
    """
    hop_slack: tuple[float, ...]
    max_frac: tuple[float, ...]   #: hottest-bucket sample fraction per hop
    sample_size: int

    def slack_for_hop(self, i: int) -> float:
        return self.hop_slack[i]


def estimate_capacities(succ, plan, m: int, cfg: ListRankConfig,
                        sample_size: int | None = None, seed: int = 0,
                        guard: float = 1.25) -> CapacityEstimate:
    """Estimate per-hop mailbox slack from a sample of the instance.

    Chase waves and gathers address the *owner of succ[x]* for (nearly)
    uniformly random x — the ruler set is a random sample of elements.
    So a host-side sample of ``succ`` destinations, bucketed by each
    hop's routing coordinate, estimates the per-hop load skew the solver
    will see. The hottest-bucket fraction f̂ plus an additive
    DKW/Hoeffding margin sqrt(ln(2s)/2k) bounds the true f w.h.p.;
    capacity is then sized for f·s times the uniform per-bucket load
    instead of a static ``capacity_slack`` guess.

    Deterministic (seeded numpy) and purely host-side: the estimate
    feeds ``api.build_specs`` before the first attempt.
    """
    succ = np.asarray(succ)
    n = succ.shape[0]
    k = min(int(sample_size or cfg.estimation_sample), n)
    rng = np.random.default_rng(np.uint32(seed) ^ np.uint32(0x5EED))
    idx = (rng.choice(n, size=k, replace=False) if k < n
           else np.arange(n, dtype=np.int64))
    owners = (succ[idx] // m).astype(np.int64)

    hop_slack, max_frac = [], []
    for hop in plan.indirection.hops:
        s = plan.hop_size(hop)
        coords = _hop_coord_np(plan, owners, hop)
        hist = np.bincount(coords, minlength=s)
        f_hat = float(hist.max()) / max(k, 1)
        margin = math.sqrt(math.log(2.0 * s + 2.0) / (2.0 * max(k, 1)))
        f_est = min(1.0, f_hat + margin)
        hop_slack.append(max(guard, f_est * s * guard))
        max_frac.append(f_hat)
    return CapacityEstimate(hop_slack=tuple(hop_slack),
                            max_frac=tuple(max_frac), sample_size=k)


def _hop_coord_np(plan, pe_ids: np.ndarray, hop: tuple[str, ...]) -> np.ndarray:
    """Host-side (numpy) mirror of ``MeshPlan.hop_coord``."""
    coord = np.zeros_like(pe_ids)
    for a in hop:
        i = plan.pe_axes.index(a)
        stride = 1
        for sz in plan.axis_sizes[i + 1:]:
            stride *= sz
        c = (pe_ids // stride) % plan.axis_sizes[i]
        coord = coord * plan.axis_sizes[i] + c
    return coord
