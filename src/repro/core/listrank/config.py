"""Configuration for the distributed list-ranking algorithms."""
from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

from repro.core.listrank.analysis import SUPERMUC, MachineModel


@dataclasses.dataclass(frozen=True)
class IndirectionSpec:
    """How messages are routed across the PE mesh (paper §2.4).

    ``hops`` is an ordered tuple of mesh-axis groups. Each hop fixes the
    destination coordinate along its axis group via one ``all_to_all``.

    - direct delivery: a single hop over all PE axes,
    - 2D-grid indirection: ``(("col",), ("row",))`` — first to the right
      column, then along the column to the right row,
    - topology-aware indirection: intra-node axis first, then the
      inter-node axis (paper: ``P_{i,u} -> P_{i,v} -> P_{j,v}``).
    """

    hops: tuple[tuple[str, ...], ...]

    @staticmethod
    def direct(pe_axes: Sequence[str]) -> "IndirectionSpec":
        return IndirectionSpec(hops=(tuple(pe_axes),))

    @staticmethod
    def grid(pe_axes: Sequence[str]) -> "IndirectionSpec":
        """One hop per mesh axis, last-axis (fastest-varying) first.

        With PE id flattened row-major over ``pe_axes``, hopping over the
        minor axis first is the paper's column-then-row routing.
        """
        return IndirectionSpec(hops=tuple((a,) for a in reversed(pe_axes)))

    @staticmethod
    def topology(intra_axes: Sequence[str], inter_axes: Sequence[str]) -> "IndirectionSpec":
        """Intra-node hop first (fast links), then inter-node (paper §2.4)."""
        return IndirectionSpec(hops=(tuple(intra_axes), tuple(inter_axes)))

    @property
    def depth(self) -> int:
        return len(self.hops)


@dataclasses.dataclass(frozen=True)
class ListRankConfig:
    """Tuning knobs for :func:`repro.core.listrank.api.rank_list`.

    Defaults follow the paper's production configuration: sparse ruling
    set with spawning, local contraction enabled, reversal avoided via
    the terminal->initial postprocessing (§2.5), pointer doubling as the
    base case after ``srs_rounds`` rounds of SRS.
    """

    #: ``"auto"`` resolves via the Corollary-1 regime check
    #: (tuner.choose_algorithm): SRS when n/p clears
    #: analysis.efficiency_threshold, plain pointer doubling below it.
    algorithm: Literal["srs", "doubling", "auto"] = "srs"
    #: number of recursive SRS rounds before the base case (paper uses 2).
    srs_rounds: int = 2
    base_case: Literal["doubling", "allgather"] = "doubling"

    #: rulers per PE as a fraction of the (effective) local input size.
    #: ``None`` derives per-level r* from the cost model
    #: (tuner.level_plan on top of analysis.r_star).
    ruler_fraction: float | None = 1.0 / 32.0
    #: machine constants (alpha/beta) for every cost-model decision.
    machine: MachineModel = SUPERMUC
    #: when no explicit IndirectionSpec is passed to rank_list, let the
    #: cost model pick direct vs grid vs topology-aware routing
    #: (tuner.choose_indirection). False keeps the direct default.
    auto_indirection: bool = False
    #: hard floor on the per-PE ruler count.
    min_rulers_per_pe: int = 4

    #: exploit locality by contracting PE-local sublists first (§2.3).
    local_contraction: bool = True
    #: avoid the explicit list reversal via §2.5 postprocessing. When
    #: False, runs the faithful Algorithm 1 with reversal preprocessing.
    avoid_reversal: bool = True
    #: deduplicate remote-gather requests per PE (§2.5 aggregation).
    dedup_requests: bool = True

    #: capacity slack over the expected per-peer message load.
    capacity_slack: float = 2.0
    #: floor for the per-peer mailbox capacity.
    min_capacity: int = 8
    #: outgoing-queue capacity as multiple of expected in-flight load.
    queue_slack: float = 4.0
    #: spawn-scan window per round (candidates examined per death batch).
    spawn_window: int = 64

    #: safety bound on chase rounds (multiplier over the n/r estimate).
    max_round_slack: float = 8.0
    #: bound on outer restarts (coverage safeguard for forward chasing).
    max_restarts: int = 4
    #: sub-problem capacity slack over the r*ln(n/r) expectation.
    sub_capacity_slack: float = 2.0

    #: sampled-splitter capacity estimation (tuner.estimate_capacities):
    #: derive per-hop mailbox slack from a host-side sample of the
    #: instance's destination distribution instead of the static
    #: ``capacity_slack`` guess. Off by default — the static derivation
    #: is the pinned golden behavior.
    capacity_estimation: bool = False
    #: sample size for the capacity pre-pass.
    estimation_sample: int = 2048

    #: transport backend (repro.core.listrank.transport): ``"auto"``
    #: follows the mesh object passed to the front door (a
    #: ``transport.SimMesh`` selects the virtual-PE simshard emulation,
    #: a real mesh the shard_map path); ``"simshard"`` forces virtual
    #: PEs even for a real mesh (same axis names/sizes, devices
    #: ignored — any p runs in-process on one device, bit-identical);
    #: ``"mesh"`` rejects a SimMesh.
    backend: Literal["auto", "mesh", "simshard"] = "auto"

    #: use the Pallas local_chase kernel for local contraction.
    use_pallas: bool = False

    #: pack all payload leaves of a message batch into one (Q, W) int32
    #: wire matrix so every routing hop is exactly one ``all_to_all``
    #: (see DESIGN.md). Off => legacy one-collective-per-leaf exchange;
    #: both paths are bit-identical.
    wire_packing: bool = True
    #: route the wire pack + bucket scatter through the Pallas
    #: ``mailbox_pack`` kernel (XLA fallback when the working set
    #: exceeds VMEM).
    use_pallas_pack: bool = False

    #: opt-in device-side telemetry plane (``repro.obs.telemetry``).
    #: A *static* flag: it is part of every jitted-program cache key
    #: (via cfg/plan), so telemetry-on programs trace and compile
    #: separately and the telemetry-off program is byte-identical to
    #: the committed goldens. When on, every stage emits a per-PE
    #: telemetry pytree (mailbox fill fractions, queue high-water
    #: marks, destination-skew summaries) as extra program outputs,
    #: aggregated host-side — no collectives are added either way.
    telemetry: bool = False

    def with_(self, **kw) -> "ListRankConfig":
        return dataclasses.replace(self, **kw)
