"""Sequential list-ranking oracle (numpy pointer chasing).

Used as the correctness reference for every distributed algorithm and
for the Pallas kernels' ``ref.py`` cross-checks.
"""
from __future__ import annotations

import numpy as np


def rank_list_seq(succ: np.ndarray, rank: np.ndarray | None = None):
    """Rank all lists by sequential traversal. O(n) time.

    Args:
      succ: int array of successor indices; terminals satisfy succ[i]==i.
      rank: optional link weights; terminals must hold 0. Defaults to the
        unweighted instance (1 for non-terminals, 0 for terminals).

    Returns:
      (succ_out, rank_out): succ_out[i] is the terminal of i's list,
      rank_out[i] the weighted distance from i to that terminal.
    """
    succ = np.asarray(succ)
    n = succ.shape[0]
    idx = np.arange(n, dtype=succ.dtype)
    if rank is None:
        rank = (succ != idx).astype(np.int64)
    rank = np.asarray(rank)
    if not np.all(rank[succ == idx] == 0):
        raise ValueError("terminal elements must carry weight 0")

    succ_out = np.empty_like(succ)
    rank_out = np.zeros(n, dtype=rank.dtype)
    # Build predecessor lists to traverse each list from its terminal
    # backwards without recursion: count in-degrees, then walk.
    has_pred = np.zeros(n, dtype=bool)
    nonterm = succ != idx
    has_pred[succ[nonterm]] = True
    # predecessor map (each element has at most one predecessor)
    pred = np.full(n, -1, dtype=np.int64)
    src = idx[nonterm]
    pred[succ[nonterm]] = src
    terminals = idx[succ == idx]
    for t in terminals:
        # walk backwards from terminal accumulating distance
        succ_out[t] = t
        rank_out[t] = 0
        cur = pred[t]
        dist = rank_out[t]
        prev = t
        while cur != -1:
            dist = dist + rank[cur]
            succ_out[cur] = t
            rank_out[cur] = dist
            prev = cur
            cur = pred[cur]
    # detect cycles: every element must have been assigned
    visited = np.zeros(n, dtype=bool)
    visited[terminals] = True
    for t in terminals:
        cur = pred[t]
        while cur != -1:
            visited[cur] = True
            cur = pred[cur]
    if not visited.all():
        raise ValueError("input contains a cycle (not a set of lists)")
    return succ_out, rank_out
