"""Sequential list-ranking oracle (vectorized numpy pointer jumping).

Used as the correctness reference for every distributed algorithm and
for the Pallas kernels' ``ref.py`` cross-checks. The original
per-terminal Python walk (an O(n) interpreter loop per list) is kept in
``tests/test_sequential.py`` as the oracle-of-oracles; this vectorized
version must match it exactly on integer weights and to float tolerance
on float weights (the accumulation order differs: backward walk vs
pairwise jumping).
"""
from __future__ import annotations

import numpy as np


def rank_list_seq(succ: np.ndarray, rank: np.ndarray | None = None):
    """Rank all lists by vectorized pointer jumping. O(n log L) work for
    maximum list length L, with no Python-level per-element loops.

    Args:
      succ: int array of successor indices; terminals satisfy succ[i]==i.
      rank: optional link weights; terminals must hold 0. Defaults to the
        unweighted instance (1 for non-terminals, 0 for terminals).

    Returns:
      (succ_out, rank_out): succ_out[i] is the terminal of i's list,
      rank_out[i] the weighted distance from i to that terminal.
    """
    succ = np.asarray(succ)
    n = succ.shape[0]
    idx = np.arange(n, dtype=succ.dtype)
    if rank is None:
        rank = (succ != idx).astype(np.int64)
    rank = np.asarray(rank)
    is_term = succ == idx
    if not np.all(rank[is_term] == 0):
        raise ValueError("terminal elements must carry weight 0")
    # a set of lists has in-degree <= 1 everywhere: merged successors
    # (trees/rho shapes) must fail loudly — jumping would happily rank
    # them, and this function is the oracle everything else trusts.
    targets = succ[~is_term]
    if np.unique(targets).size != targets.size:
        raise ValueError(
            "an element has two predecessors (not a set of lists)")

    # Pointer jumping: after k steps s[i] is 2^k links ahead (clamped at
    # the terminal) and w[i] the weight sum over the links traversed —
    # terminals are fixed points contributing 0, so both converge to the
    # answer once 2^k exceeds every list length.
    s = succ.astype(np.int64)
    w = rank.copy()
    for _ in range(max(int(n).bit_length(), 1) + 1):
        if np.all(is_term[s]):
            break
        w = w + w[s]
        s = s[s]
    # A set of lists converges within ceil(log2 n)+1 jumps; anything
    # still short of a true terminal is on a cycle. (Cycles of even
    # length collapse to spurious fixed points under jumping, so the
    # check must consult the *original* terminal set.)
    if not np.all(is_term[s]):
        raise ValueError("input contains a cycle (not a set of lists)")
    return s.astype(succ.dtype), w.astype(rank.dtype)
