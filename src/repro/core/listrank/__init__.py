"""Distributed list ranking in JAX — the paper's core contribution.

Implements the sparse-ruling-set (SRS) algorithm with ruler spawning
[Sibeyn'99; Sanders/Schimek/Uhl/Weidmann 2026], pointer doubling (Wyllie)
as baseline and base case, local contraction for locality exploitation,
and direct / grid / topology-aware message indirection mapped onto JAX
mesh collectives.
"""
from repro.core.listrank.config import ListRankConfig, IndirectionSpec
from repro.core.listrank.api import rank_list, rank_list_with_stats
from repro.core.listrank.resume import SolveExhausted
from repro.core.listrank.faults import (FaultSpec, FaultInjector,
                                        InjectedFault, CorruptedState)
from repro.core.listrank.sequential import rank_list_seq
from repro.core.listrank.transport import SimMesh, sim_mesh
from repro.core.listrank import instances, analysis, tuner

#: batched multi-instance front door (lives in repro.core.treealg.batch,
#: re-exported here because it is the list-level serving API). Lazy to
#: keep the import graph acyclic: treealg imports listrank submodules.
_TREEALG_EXPORTS = ("rank_lists", "rank_lists_with_stats", "solve_forest")

__all__ = [
    "ListRankConfig",
    "IndirectionSpec",
    "rank_list",
    "rank_list_with_stats",
    "rank_list_seq",
    "SolveExhausted",
    "FaultSpec",
    "FaultInjector",
    "InjectedFault",
    "CorruptedState",
    "SimMesh",
    "sim_mesh",
    "instances",
    "analysis",
    "tuner",
    *_TREEALG_EXPORTS,
]


def __getattr__(name):
    if name in _TREEALG_EXPORTS:
        from repro.core.treealg import batch
        return getattr(batch, name)
    raise AttributeError(
        f"module {__name__!r} has no attribute {name!r}")
