"""Jaxpr introspection: count primitives (notably collectives) in a
traced program.

The paper's §2.6 alpha-beta model says per-round *collective count* is
the quantity that governs scaling; the packed wire format exists to
drive it to one ``all_to_all`` per hop. These helpers make that claim
checkable — tests assert the exact count and the exchange
microbenchmark records it in the perf trajectory.
"""
from __future__ import annotations

from typing import Any, Callable

import jax

#: primitive names that hit the interconnect.
COLLECTIVE_PRIMS = ("all_to_all", "all_gather", "psum", "ppermute",
                    "reduce_scatter", "all_reduce")


def _sub_jaxprs(value: Any):
    """Yield jaxprs nested inside an eqn param (pjit, while, cond, ...)."""
    if hasattr(value, "eqns"):          # Jaxpr
        yield value
    elif hasattr(value, "jaxpr"):       # ClosedJaxpr
        yield value.jaxpr
    elif isinstance(value, (list, tuple)):
        for v in value:
            yield from _sub_jaxprs(v)


def count_primitives(jaxpr) -> dict[str, int]:
    """Recursively count primitive applications in a (closed) jaxpr."""
    counts: dict[str, int] = {}

    def visit(jx):
        for eqn in jx.eqns:
            name = eqn.primitive.name
            counts[name] = counts.get(name, 0) + 1
            for v in eqn.params.values():
                for sub in _sub_jaxprs(v):
                    visit(sub)

    visit(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr)
    return counts


def collective_counts(fn: Callable, *args, **kwargs) -> dict[str, int]:
    """Trace ``fn(*args)`` and count collective primitives in it."""
    jaxpr = jax.make_jaxpr(fn, **kwargs)(*args)
    counts = count_primitives(jaxpr)
    return {k: v for k, v in counts.items() if k in COLLECTIVE_PRIMS}
