"""Jaxpr introspection: count primitives (notably collectives) in a
traced program.

The paper's §2.6 alpha-beta model says per-round *collective count* is
the quantity that governs scaling; the packed wire format exists to
drive it to one ``all_to_all`` per hop. These helpers make that claim
checkable — tests assert the exact count and the exchange
microbenchmark records it in the perf trajectory.
"""
from __future__ import annotations

from typing import Any, Callable

import jax

#: primitive names that hit the interconnect.
COLLECTIVE_PRIMS = ("all_to_all", "all_gather", "psum", "ppermute",
                    "reduce_scatter", "all_reduce")

# Under the simshard backend the vmap batching rules erase the
# collective eqns (an all_to_all becomes a transpose), so the transport
# wraps each collective in a pjit named ``simshard_<prim>``. Counting
# the marker as ``<prim>`` keeps every collective-count pin meaningful
# on both backends — the same program traces to the same counts.
from repro.core.listrank.transport import SIM_MARKER_PREFIX


def _sim_marker(eqn) -> str | None:
    """The collective a simshard marker eqn stands for, else None."""
    name = eqn.params.get("name")
    if isinstance(name, str) and name.startswith(SIM_MARKER_PREFIX):
        prim = name[len(SIM_MARKER_PREFIX):]
        if prim in COLLECTIVE_PRIMS:
            return prim
    return None


def _sub_jaxprs(value: Any):
    """Yield jaxprs nested inside an eqn param (pjit, while, cond, ...)."""
    if hasattr(value, "eqns"):          # Jaxpr
        yield value
    elif hasattr(value, "jaxpr"):       # ClosedJaxpr
        yield value.jaxpr
    elif isinstance(value, (list, tuple)):
        for v in value:
            yield from _sub_jaxprs(v)


def count_primitives(jaxpr) -> dict[str, int]:
    """Recursively count primitive applications in a (closed) jaxpr."""
    counts: dict[str, int] = {}

    def visit(jx):
        for eqn in jx.eqns:
            marker = _sim_marker(eqn)
            if marker is not None:
                # one marker == one simulated collective; its body holds
                # only the vmap-lowered data movement — don't recurse.
                counts[marker] = counts.get(marker, 0) + 1
                continue
            name = eqn.primitive.name
            counts[name] = counts.get(name, 0) + 1
            for v in eqn.params.values():
                for sub in _sub_jaxprs(v):
                    visit(sub)

    visit(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr)
    return counts


def collective_counts(fn: Callable, *args, **kwargs) -> dict[str, int]:
    """Trace ``fn(*args)`` and count collective primitives in it."""
    jaxpr = jax.make_jaxpr(fn, **kwargs)(*args)
    counts = count_primitives(jaxpr)
    return {k: v for k, v in counts.items() if k in COLLECTIVE_PRIMS}


def _aval_bytes(var) -> int:
    aval = var.aval
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    out = dtype.itemsize
    for d in shape:
        out *= int(d)
    return out


def payload_bytes(jaxpr) -> dict[str, int]:
    """Per-collective-primitive payload bytes of a (closed) jaxpr.

    Sums the operand (invar) aval sizes of every collective eqn,
    recursing into nested jaxprs exactly like :func:`count_primitives`.
    Collective *count* alone cannot distinguish "one packed
    ``all_to_all``" from "one ``all_to_all`` that grew a second hidden
    word-plane"; counting operand bytes pins the wire-format volume
    too — each packed hop must ship exactly ``width * hop_size * cap``
    int32 words and nothing else.
    """
    out: dict[str, int] = {}

    def visit(jx):
        for eqn in jx.eqns:
            name = _sim_marker(eqn) or eqn.primitive.name
            if name in COLLECTIVE_PRIMS:
                # NB simshard marker operands carry the virtual-PE batch
                # axis, so marker bytes are p x the per-PE mesh bytes —
                # byte pins are a mesh-backend property; count parity is
                # the cross-backend invariant.
                out[name] = out.get(name, 0) + sum(
                    _aval_bytes(v) for v in eqn.invars)
                if _sim_marker(eqn) is not None:
                    continue
            for v in eqn.params.values():
                for sub in _sub_jaxprs(v):
                    visit(sub)

    visit(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr)
    return out


def collective_footprint(fn: Callable, *args, **kwargs) -> dict[str, tuple]:
    """Trace ``fn(*args)`` and report ``{prim: (count, payload_bytes)}``
    for every collective primitive in the program — the §2.6 model's
    two levers (startups and volume) from one trace."""
    jaxpr = jax.make_jaxpr(fn, **kwargs)(*args)
    counts = count_primitives(jaxpr)
    bytes_ = payload_bytes(jaxpr)
    return {k: (counts[k], bytes_.get(k, 0))
            for k in counts if k in COLLECTIVE_PRIMS}
