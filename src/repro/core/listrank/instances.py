"""Input-instance generators (paper §3, Input Instances).

All generators return ``(succ, rank)`` numpy arrays over ``n`` elements,
with terminals pointing to themselves and carrying weight 0. All are
fully vectorized (paper-scale instances, n >= 10^7, build in seconds);
``tests/test_instances.py`` keeps the original loop implementations as
the equality oracle.

- :func:`gen_list`: the paper's List(n/p, gamma) — an identity chain
  with a gamma-fraction of labels randomly permuted. gamma=0 gives each
  PE a contiguous sublist (perfect locality); gamma=1 a fully random
  permutation (no locality).
- :func:`gen_random_lists`: a forest of random lists (multi-list case).
- :func:`gen_euler_tour`: the Euler tour of a random tree (or, with
  ``num_trees``, a forest); two tree models mimic the paper's GNM (no
  locality) and RGG2D (high locality) BFS-tree instances, and
  ``weighted=True`` gives the ±1 depth weights consumed by
  ``repro.core.treealg``.
"""
from __future__ import annotations

import numpy as np


def _as_succ_dtype(a: np.ndarray) -> np.ndarray:
    return a.astype(np.int32)


def gen_list(n: int, gamma: float, seed: int = 0, num_lists: int = 1):
    """Paper instance List(n, gamma): chain succ[i]=i+1 with a random
    relabeling applied to a gamma-fraction of positions.

    ``num_lists`` splits the chain into that many independent lists by
    cutting at evenly spaced points (each cut creates a terminal).
    """
    if not 0.0 <= gamma <= 1.0:
        raise ValueError("gamma must be in [0,1]")
    if n == 0:
        return np.zeros(0, np.int32), np.zeros(0, np.int32)
    rng = np.random.default_rng(seed)
    labels = np.arange(n, dtype=np.int64)
    k = int(round(gamma * n))
    if k > 1:
        pos = rng.choice(n, size=k, replace=False)
        labels[pos] = labels[rng.permutation(pos)]
    # chain over labels: labels[j] -> labels[j+1], self-loop at cuts
    succ = np.empty(n, dtype=np.int64)
    succ[labels[:-1]] = labels[1:]
    succ[labels[-1]] = labels[-1]
    cuts = np.linspace(0, n, num_lists + 1).astype(np.int64)[1:]
    ends = cuts - 1
    ends = ends[(ends >= 0) & (ends < n)]
    succ[labels[ends]] = labels[ends]
    idx = np.arange(n)
    rank = (succ != idx).astype(np.int64)
    return _as_succ_dtype(succ), rank.astype(np.int32)


def gen_random_lists(n: int, num_lists: int, seed: int = 0, weighted: bool = False):
    """A forest of ``num_lists`` random lists over a random permutation."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    succ = np.empty(n, dtype=np.int64)
    cuts = np.sort(rng.choice(np.arange(1, n), size=num_lists - 1, replace=False)) if num_lists > 1 else np.array([], dtype=np.int64)
    bounds = np.concatenate([[0], cuts, [n]])
    # chain the whole permutation, then self-loop every segment end
    succ[perm[:-1]] = perm[1:]
    seg_ends = perm[bounds[1:].astype(np.int64) - 1]
    succ[seg_ends] = seg_ends
    idx = np.arange(n)
    if weighted:
        rank = rng.integers(0, 100, size=n).astype(np.int64)
        rank[succ == idx] = 0
    else:
        rank = (succ != idx).astype(np.int64)
    return _as_succ_dtype(succ), rank.astype(np.int32)


def _random_tree_parents(n: int, rng: np.random.Generator, locality: bool) -> np.ndarray:
    """parent[i] for i>=1; node 0 is the root.

    ``locality=False``: random attachment (GNM-BFS-like, no locality).
    ``locality=True``: attach to a recent node (RGG2D-BFS-like: tree
    edges connect index-close nodes, so a block-distributed Euler tour
    has high locality).
    """
    parent = np.zeros(n, dtype=np.int64)
    if locality:
        window = max(1, n // 64)
        lo = np.maximum(0, np.arange(1, n) - window)
        parent[1:] = lo + (rng.random(n - 1) * (np.arange(1, n) - lo)).astype(np.int64)
    else:
        parent[1:] = (rng.random(n - 1) * np.arange(1, n)).astype(np.int64)
    return parent


def gen_tree_parents(n_nodes: int, seed: int = 0, locality: bool = False,
                     num_trees: int = 1) -> np.ndarray:
    """A random rooted tree (or ``num_trees`` forest) as a parent array
    with ``parent[root] == root`` — the input shape of
    ``repro.core.treealg``. Same tree models as :func:`gen_euler_tour`
    (which consumes exactly this array: same seed, same tree)."""
    rng = np.random.default_rng(seed)
    parent = _random_tree_parents(n_nodes, rng, locality)
    if not 1 <= num_trees <= max(n_nodes, 1):
        raise ValueError("num_trees must be in [1, n_nodes]")
    if num_trees > 1:
        # cut the tree into a forest: extra roots detach their subtree.
        # Drawn after the parent array so the num_trees=1 RNG stream is
        # unchanged (same backward-compat discipline as gen_list).
        extra = rng.choice(np.arange(1, n_nodes), size=num_trees - 1,
                           replace=False)
        parent[extra] = extra
    return parent


def adjacency_links(parent: np.ndarray):
    """(first_child, next_sib) per node (−1 = none) under the
    ascending-child-id adjacency order: a stable argsort of the
    non-root parent entries groups children by parent with ascending
    child id inside each run. The single definition of the tour's
    adjacency order — shared by :func:`gen_euler_tour` and the
    device-construction oracle ``treealg.euler.oracle_tour``."""
    n = parent.shape[0]
    nodes = np.arange(n, dtype=np.int64)
    cand = nodes[parent != nodes]
    order = np.argsort(parent[cand], kind="stable")
    childs = cand[order]
    cpar = parent[childs]
    first_child = np.full(n, -1, dtype=np.int64)
    next_sib = np.full(n, -1, dtype=np.int64)
    if childs.size:
        is_first = np.ones(childs.size, dtype=bool)
        is_first[1:] = cpar[1:] != cpar[:-1]
        first_child[cpar[is_first]] = childs[is_first]
        same = cpar[1:] == cpar[:-1]
        next_sib[childs[:-1][same]] = childs[1:][same]
    return first_child, next_sib


def gen_euler_tour(n_nodes: int, seed: int = 0, locality: bool = False,
                   weighted: bool = False, num_trees: int = 1):
    """Euler tour of a random tree (or forest) as a list-ranking instance.

    The tour has one element per arc; arc (u,v) is followed by the next
    arc around v after (v,u) in the circular adjacency order. Each tree
    is rooted (node 0, plus ``num_trees - 1`` random extra roots for
    forests) by cutting the arc returning to its root; roots' own arc
    slots become weight-0 self-loops, so the layout stays
    down(c) = 2(c-1), up(c) = 2(c-1)+1 regardless of the forest shape.

    ``weighted=True`` assigns the depth weights: +1 on down-arcs, -1 on
    up-arcs (terminals and root dummies carry 0 as the solver requires),
    so a node's depth is recoverable from the weighted rank of its
    down-arc alone (``treealg.ops``: depth = 2 - rank±(down)).

    Returns (succ, rank, arcs): arcs[i] = (u, v) for tour element i
    (roots' dummy slots hold (r, r)).
    """
    parent = gen_tree_parents(n_nodes, seed=seed, locality=locality,
                              num_trees=num_trees)
    # arcs: for each non-root node c with parent q: down-arc (q->c) id 2k,
    # up-arc (c->q) id 2k+1 where k = c-1.
    n_arcs = 2 * (n_nodes - 1)
    if n_arcs == 0:
        return np.zeros(0, np.int32), np.zeros(0, np.int32), np.zeros((0, 2), np.int64)
    nodes = np.arange(n_nodes, dtype=np.int64)
    is_root = parent == nodes
    cand = nodes[~is_root]
    first_child, next_sib = adjacency_links(parent)

    # next arc after entering node v via arc a: standard Euler tour:
    #   after down-arc (q->c): first child arc of c, else up-arc (c->q)
    #   after up-arc (c->q): next sibling down-arc, else up-arc (q->pq)
    c = cand
    down = 2 * (c - 1)
    up = down + 1
    q = parent[c]
    fc = first_child[c]
    ns = next_sib[c]
    idx = np.arange(n_arcs)
    succ = np.empty(n_arcs, dtype=np.int64)
    succ[idx] = idx  # roots' dummy arc slots self-loop
    succ[down] = np.where(fc >= 0, 2 * (fc - 1), up)
    succ[up] = np.where(ns >= 0, 2 * (ns - 1),
                        np.where(is_root[q], up,  # tour ends at its root
                                 2 * (q - 1) + 1))
    if weighted:
        rank = np.where(idx % 2 == 0, 1, -1).astype(np.int64)
        rank[succ == idx] = 0
    else:
        rank = (succ != idx).astype(np.int64)
    arcs = np.empty((n_arcs, 2), dtype=np.int64)
    r_extra = nodes[1:][is_root[1:]]
    arcs[2 * (r_extra - 1), 0] = r_extra
    arcs[2 * (r_extra - 1), 1] = r_extra
    arcs[2 * (r_extra - 1) + 1, 0] = r_extra
    arcs[2 * (r_extra - 1) + 1, 1] = r_extra
    arcs[down, 0] = q
    arcs[down, 1] = c
    arcs[up, 0] = c
    arcs[up, 1] = q
    return _as_succ_dtype(succ), rank.astype(np.int32), arcs


def gen_graph_edges(n_nodes: int, n_edges: int, seed: int = 0,
                    locality: bool = False,
                    num_components: int = 1) -> np.ndarray:
    """Random undirected edge list with a controlled component count
    (the ``repro.core.graphalg`` input families).

    Nodes split into ``num_components`` contiguous blocks; each block
    gets a random spanning tree (the same two attachment models as
    :func:`gen_tree_parents`: uniform = GNM-BFS-like, windowed =
    RGG2D-like) plus ``n_edges - (n_nodes - num_components)`` extra
    random intra-block edges, so the edge list has *exactly*
    ``num_components`` connected components. ``locality=True`` draws
    every edge between index-close nodes, mimicking an RGG2D graph's
    block-distribution locality. Fully vectorized; RNG discipline
    matches the list generators (one ``default_rng(seed)`` stream,
    extra-edge draws strictly after the tree draws).

    Returns an ``(n_edges, 2)`` int64 array in randomized order and
    orientation (self-loops never occur, parallel edges may).
    """
    if n_nodes <= 0:
        raise ValueError("n_nodes must be positive")
    if not 1 <= num_components <= n_nodes:
        raise ValueError("num_components must be in [1, n_nodes]")
    tree_edges = n_nodes - num_components
    if n_edges < tree_edges:
        raise ValueError(
            f"n_edges={n_edges} cannot connect {n_nodes} nodes into "
            f"{num_components} components (need >= {tree_edges})")
    rng = np.random.default_rng(seed)
    bounds = np.linspace(0, n_nodes, num_components + 1).astype(np.int64)
    starts, ends = bounds[:-1], bounds[1:]
    # block id and block start per node (blocks are contiguous)
    blk = np.searchsorted(ends, np.arange(n_nodes), side="right")
    lo_of = starts[blk]
    hi_of = ends[blk]

    edges = np.empty((n_edges, 2), dtype=np.int64)
    # spanning trees: node i attaches to a strictly-earlier node of its
    # own block (so block starts are the roots) — uniform over the
    # block prefix, or over a trailing window for the RGG2D-like model.
    child = np.arange(n_nodes)[np.arange(n_nodes) != lo_of]
    lo = lo_of[child]
    if locality:
        window = max(1, n_nodes // 64)
        lo = np.maximum(lo, child - window)
    edges[:tree_edges, 0] = child
    edges[:tree_edges, 1] = lo + (rng.random(tree_edges)
                                  * (child - lo)).astype(np.int64)
    # extra edges: first endpoint uniform over non-singleton blocks,
    # second a distinct node of the same block (windowed if locality)
    extra = n_edges - tree_edges
    if extra:
        cand = np.arange(n_nodes)[(hi_of - lo_of) > 1]
        if cand.size == 0:
            raise ValueError("extra edges require a block with >= 2 nodes")
        u = cand[rng.integers(0, cand.size, size=extra)]
        lo2, hi2 = lo_of[u], hi_of[u]
        if locality:
            window = max(1, n_nodes // 64)
            lo2 = np.maximum(lo2, u - window)
            hi2 = np.minimum(hi2, u + window + 1)
        # draw from the block minus u itself: sample [lo2, hi2-1) and
        # shift values >= u up by one
        v = lo2 + (rng.random(extra) * (hi2 - lo2 - 1)).astype(np.int64)
        v = np.where(v >= u, v + 1, v)
        edges[tree_edges:, 0] = u
        edges[tree_edges:, 1] = v
    # randomized order and orientation (inputs must not leak the
    # construction's child->parent structure)
    flip = rng.random(n_edges) < 0.5
    edges[flip] = edges[flip, ::-1]
    return edges[rng.permutation(n_edges)]


def pad_to_multiple(succ: np.ndarray, rank: np.ndarray, p: int):
    """Pad with self-loop singletons so n is divisible by p."""
    n = succ.shape[0]
    pad = (-n) % p
    if pad == 0:
        return succ, rank
    extra = np.arange(n, n + pad, dtype=succ.dtype)
    return np.concatenate([succ, extra]), np.concatenate([rank, np.zeros(pad, rank.dtype)])


def locality_fraction(succ: np.ndarray, p: int) -> float:
    """Fraction of elements whose successor lives on the same PE
    (block distribution) — the paper's delta."""
    n = succ.shape[0]
    m = n // p
    owner = np.arange(n) // m
    return float(np.mean(owner == (np.asarray(succ) // m)))
