"""Deterministic fault injection for the level-resumable solver.

The staged driver (:mod:`repro.core.listrank.resume`) consults a
:class:`FaultInjector` around every stage it executes; each
:class:`FaultSpec` names one fault to fire at one (stage kind, level)
boundary. Faults are *host-driven*: they never perturb the traced
per-PE program, so a recovered solve replays the exact same device
computation as a straight-through solve — which is what lets the
recovery tests pin byte-identity against the committed goldens.

Injection taxonomy (DESIGN.md §11):

- ``overflow``: the driver treats the named capacity family as fatally
  overflowed after the stage, without touching device state — the
  escalate-and-resume path runs exactly as it would for a real
  overflow, but the re-run (with larger caps) reproduces the clean
  counters byte-for-byte.
- ``pe_loss``: an exception raised before the stage executes (a crashed
  rank); the driver restores from the latest checkpoint, or restarts
  from scratch when there is none.
- ``corrupt``: a recognizable sentinel scribbled over one PE's plane of
  a boundary store (a corrupted mailbox/successor plane); caught by the
  driver's host-side invariant validation, then recovered like a crash.
- ``preempt``: sets the supervisor's preemption flag (as SIGTERM/SIGINT
  would); the driver writes a blocking checkpoint and raises
  ``Preempted``.

Each spec fires exactly once (the first time its filter matches) and is
then retired, so the recovery re-run of the same stage proceeds clean.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

#: the sentinel ``corrupt`` scribbles into an int32 plane — far outside
#: any valid global id, so state validation cannot miss it.
CORRUPT_SENTINEL = -0x5EED5EED


class InjectedFault(RuntimeError):
    """Raised by ``pe_loss`` injection (stands in for a crashed PE)."""


class CorruptedState(RuntimeError):
    """Raised when boundary-state validation finds an invariant
    violation (e.g. an injected corrupted plane)."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault to inject at one stage boundary.

    ``stage`` filters by stage kind (``"prep"``, ``"descend"``,
    ``"base"``, ``"ascend"``, ``"pd"``, ``"post"``; None matches any),
    ``level`` by recursion level (None matches any). ``family`` names
    the capacity family for ``overflow``; ``pe`` and ``plane`` locate
    the scribble for ``corrupt``.
    """
    kind: str                    # overflow | pe_loss | corrupt | preempt
    stage: str | None = None
    level: int | None = None
    family: str = "chase"
    pe: int = 0
    plane: str = "succ"

    def __post_init__(self):
        if self.kind not in ("overflow", "pe_loss", "corrupt", "preempt"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.kind == "overflow" and self.family not in (
                "chase", "sub", "gather"):
            raise ValueError(f"unknown capacity family {self.family!r}")


class FaultInjector:
    """Matches pending :class:`FaultSpec` entries against stage
    boundaries; every spec fires at most once."""

    def __init__(self, specs: Sequence[FaultSpec] | FaultSpec):
        if isinstance(specs, FaultSpec):
            specs = (specs,)
        self._pending = list(specs)
        self.fired: list[FaultSpec] = []

    def _take(self, kind: str, stage: str, level: int) -> FaultSpec | None:
        for f in self._pending:
            if f.kind != kind:
                continue
            if f.stage is not None and f.stage != stage:
                continue
            if f.level is not None and f.level != level:
                continue
            self._pending.remove(f)
            self.fired.append(f)
            return f
        return None

    @property
    def pending(self) -> tuple[FaultSpec, ...]:
        return tuple(self._pending)

    def crash_before(self, stage: str, level: int) -> None:
        """Raise :class:`InjectedFault` if a ``pe_loss`` matches."""
        f = self._take("pe_loss", stage, level)
        if f is not None:
            raise InjectedFault(
                f"injected PE loss before stage {stage}@L{level}")

    def overflow_after(self, stage: str, level: int) -> str | None:
        """The capacity family to treat as fatally overflowed, if any."""
        f = self._take("overflow", stage, level)
        return f.family if f is not None else None

    def corrupt_after(self, stage: str, level: int) -> FaultSpec | None:
        return self._take("corrupt", stage, level)

    def preempt_after(self, stage: str, level: int) -> bool:
        return self._take("preempt", stage, level) is not None
