"""Sparse ruling set with ruler spawning (paper Algorithm 1 + §2.2-2.5).

Structure (all inside one ``shard_map``-ed program):

  solve_store(level):
    if base level: pointer doubling (or all-gather) base case
    else:
      chase: bulk-synchronous wave rounds with ruler spawning
      extract ruler∪terminal subproblem into a sparse store
      solve_store(level+1)
      write back + ruler propagation (remote gather, aggregated)

``solve_store`` ranks every element of the instance w.r.t. the *initial*
element of its list (the natural direction of forward chasing). The
caller fixes the direction either by the §2.5 postprocess (default) or
by running on the reversed instance (faithful Algorithm 1) — see api.py.

Static-shape adaptations (see DESIGN.md): fixed-capacity mailboxes with
leftover re-queuing, a windowed permutation scan for spawning, and an
outer restart loop that guarantees coverage regardless of capacity or
spawn-window choices. Every potential overflow is surfaced in ``stats``
and triggers a retry with doubled capacities in the driver.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.listrank import store as store_lib
from repro.core.listrank.config import ListRankConfig
from repro.core.listrank.doubling import allgather_solve, doubling_solve
from repro.core.listrank.exchange import (MeshPlan, compact_queue,
                                          remote_gather, route_compact)
from repro.obs import telemetry as tele_lib

INT_MAX = jnp.iinfo(jnp.int32).max


@dataclasses.dataclass(frozen=True)
class LevelSpec:
    """Static per-recursion-level capacities (host-derived in api.py)."""
    cap: int                      # store capacity at this level
    r_static: int                 # static ruler-count bound per PE
    mail_caps: tuple[int, ...]    # per-hop mailbox capacity
    queue_cap: int
    spawn_window: int
    max_rounds: int
    cap_sub: int                  # capacity of the next level's store
    gather_req_cap: int
    gather_resp_cap: int
    base: bool                    # True => solve with the base case
    #: ruler fraction of the live instance (tuner.level_plan — the same
    #: derivation that sized r_static, so r_target <= r_static).
    ruler_frac: float
    #: bound on outer chase restarts (ListRankConfig.max_restarts).
    max_restarts: int


#: schema of the solver's stat counters (repro.obs.metrics ingests
#: host_stats under these help strings; keep in sync with zero_stats).
STAT_HELP = {
    "rounds": "chase rounds executed across all levels",
    "restarts": "outer chase restarts (coverage stragglers)",
    "chase_msgs": "chase wave messages routed",
    "spawn_lost": "spawn proposals dropped by the spawn window",
    "rulers": "rulers selected (final attempt, all levels)",
    "sub_size": "recursion subproblem elements extracted",
    "dropped": "FATAL: chase mailbox/queue overflow drops",
    "sub_overflow": "FATAL: recursion sub-store overflow",
    "store_miss": "FATAL: store lookups routed to a non-owner",
    "undelivered": "FATAL: gather/reversal/fixup messages undelivered",
    "pd_rounds": "pointer-doubling rounds (base case or pd algorithm)",
    "pd_msgs": "pointer-doubling gather messages",
    "reversal_msgs": "Algorithm-1 reversal preprocessing messages",
    "fixup_msgs": "\u00a72.3 restoration fixup messages",
    "max_queue": "peak chase queue occupancy (gauge)",
    "attempts": "driver attempts (1 + capacity escalations)",
}


def zero_stats():
    z = jnp.int32(0)
    return {
        "rounds": z, "restarts": z, "chase_msgs": z, "spawn_lost": z,
        "rulers": z, "sub_size": z, "dropped": z, "sub_overflow": z,
        "store_miss": z, "undelivered": z, "pd_rounds": z, "pd_msgs": z,
        "reversal_msgs": z, "fixup_msgs": z, "max_queue": z,
    }


def _merge(a, b):
    out = dict(a)
    for k, v in b.items():
        if k == "telemetry":
            # device-side telemetry pytree (cfg.telemetry): HWM leaves
            # max-merge, counters add — see repro.obs.telemetry.merge.
            out[k] = tele_lib.merge(a.get(k), v)
        elif k == "max_queue":
            out[k] = jnp.maximum(a[k], v)
        else:
            out[k] = a[k] + v
    return out


def gather_until_done(plan: MeshPlan, targets, valid, owner_of, lookup_fn,
                      req_cap, resp_cap, dedup, max_iters=16):
    """remote_gather retried until every valid query is answered.

    Abandoned in-flight fragments from a failed pass are simply dropped
    and re-requested — gathers are read-only, hence idempotent."""
    shapes = jax.eval_shape(lookup_fn, targets, valid)
    results = {k: jnp.zeros(s.shape, s.dtype) for k, s in shapes.items()}

    def cond(c):
        _, _, remaining_n, it, _, _ = c
        return (remaining_n > 0) & (it < max_iters)

    def body(c):
        results, remaining, _, it, msgs, tele = c
        resp, answered, st = remote_gather(plan, targets, remaining, owner_of,
                                           lookup_fn, req_cap, resp_cap, dedup)
        results = {k: jnp.where(answered, resp[k], v) for k, v in results.items()}
        remaining = remaining & ~answered
        rn = plan.psum(jnp.sum(remaining).astype(jnp.int32))
        if plan.telemetry:
            tele = tele_lib.merge(tele, st["telemetry"])
        return (results, remaining, rn, it + 1,
                msgs + st["req_sent"] + st["resp_sent"], tele)

    tele0 = (tele_lib.route_zero(plan.indirection.depth)
             if plan.telemetry else None)
    init = (results, valid, jnp.int32(1), jnp.int32(0), jnp.int32(0), tele0)
    results, remaining, rn, _, msgs, tele = lax.while_loop(cond, body, init)
    out_stats = {"undelivered": rn, "msgs": msgs}
    if plan.telemetry:
        out_stats["telemetry"] = tele
    return results, ~remaining & valid, out_stats


def route_until_done(plan: MeshPlan, caps, payload, dest, valid,
                     deliver_fn, carry, max_iters=64):
    """Route messages, applying deliver_fn(carry, delivered, dvalid) each
    round, re-queuing leftovers until everything is delivered. Leftover
    compaction is fused into the routing sort (route_compact).

    Returns ``(carry, pending, msgs, tele)`` — ``tele`` is the merged
    per-PE routing telemetry (None unless ``plan.telemetry``)."""
    q = dest.shape[0]

    def cond(c):
        return (c[4] > 0) & (c[5] < max_iters)

    def body(c):
        carry, payload, dest, valid, _, it, msgs, tele = c
        delivered, dval, (npl, nd, nv), dropped, st = route_compact(
            plan, caps, [(payload, dest, valid)], q)
        carry = deliver_fn(carry, delivered, dval)
        pending = plan.psum(jnp.sum(nv).astype(jnp.int32) + dropped)
        if plan.telemetry:
            tele = tele_lib.merge(tele, st["telemetry"])
        return (carry, npl, nd, nv, pending, it + 1, msgs + sum(st["sent"]),
                tele)

    tele0 = (tele_lib.route_zero(plan.indirection.depth)
             if plan.telemetry else None)
    pend0 = plan.psum(jnp.sum(valid).astype(jnp.int32))
    init = (carry, payload, dest, valid, pend0, jnp.int32(0), jnp.int32(0),
            tele0)
    carry, _, _, _, pending, _, msgs, tele = lax.while_loop(cond, body, init)
    return carry, pending, msgs, tele


# --------------------------------------------------------------------------
# chase phase
# --------------------------------------------------------------------------

def _make_rulers(st, visited, is_ruler, slots, sel):
    """Mark slots as rulers and build their wave emissions (Alg.1 l.3-5,
    9-11): emit (rank[r], succ[r], r), then succ[r]<-r, rank[r]<-0."""
    cap = st.ids.shape[0]
    slots_i = jnp.minimum(slots, cap - 1)
    slots_c = jnp.where(sel, slots, cap)
    gid = st.ids[slots_i]
    succ_r = st.succ[slots_i]
    rank_r = st.rank[slots_i]
    emit_valid = sel & (succ_r != gid)
    emissions = ({"target": succ_r, "ruler": gid, "weight": rank_r}, emit_valid)
    st = store_lib.scatter_update(st, slots_c, sel, succ=gid,
                                  rank=jnp.zeros_like(rank_r))
    visited = visited.at[slots_c].set(True, mode="drop")
    is_ruler = is_ruler.at[slots_c].set(True, mode="drop")
    return st, visited, is_ruler, emissions


def _launch_from_perm(st, visited, is_ruler, perm, r_target):
    """Exact ruler selection: the first r_target unvisited slots in perm
    order (one O(cap log cap) pass; level start and restarts)."""
    cap = st.ids.shape[0]
    pidx = jnp.minimum(perm, cap - 1)
    ok = (perm < cap) & st.valid[pidx] & ~visited[pidx]
    cnt = jnp.cumsum(ok.astype(jnp.int32))
    sel = ok & (cnt <= r_target)
    consumed = jnp.minimum(
        jnp.searchsorted(cnt, r_target, side="left").astype(jnp.int32) + 1,
        jnp.int32(perm.shape[0]))
    out = _make_rulers(st, visited, is_ruler, jnp.where(sel, pidx, cap), sel)
    return out, consumed, jnp.sum(sel).astype(jnp.int32)


def _spawn(st, visited, is_ruler, perm, perm_pos, window, k):
    """Windowed spawn of up to k rulers from the unvisited pool (§2.5
    Ruler Selection and Spawning: scan a random permutation onward from
    the current position, skipping visited elements)."""
    cap = st.ids.shape[0]
    w = lax.dynamic_slice(perm, (perm_pos,), (window,))
    widx = jnp.minimum(w, cap - 1)
    ok = (w < cap) & st.valid[widx] & ~visited[widx]
    cnt = jnp.cumsum(ok.astype(jnp.int32))
    sel = ok & (cnt <= k)
    avail = cnt[-1]
    spawned = jnp.minimum(k, avail)
    consumed = jnp.where(
        avail <= k, jnp.int32(window),
        jnp.searchsorted(cnt, k, side="left").astype(jnp.int32) + 1)
    st, visited, is_ruler, emissions = _make_rulers(
        st, visited, is_ruler, jnp.where(sel, widx, cap), sel)
    new_pos = jnp.minimum(perm_pos + consumed, jnp.int32(cap))
    return st, visited, is_ruler, new_pos, emissions, k - spawned


def _zero_frag(n: int, rank_dtype):
    """An all-invalid chase-message fragment of static size n."""
    payload = {"target": jnp.zeros(n, jnp.int32),
               "ruler": jnp.zeros(n, jnp.int32),
               "weight": jnp.zeros(n, rank_dtype)}
    return payload, jnp.zeros(n, jnp.int32), jnp.zeros(n, jnp.bool_)


def _chase(plan: MeshPlan, spec: LevelSpec, owner_of, st, visited, is_ruler,
           is_sub, forced, perm, r_target, stats):
    """The wave loop: launch → (route → process → spawn)*, with an outer
    restart loop guaranteeing coverage.

    The round state is three fixed-shape fragments — the compacted
    leftover queue plus the previous round's forward/spawn emissions —
    routed together by ``route_compact``, whose bucket sort doubles as
    queue compaction: one stable sort per hop per round, no separate
    requeue pass (see DESIGN.md)."""
    cap = st.ids.shape[0]
    qc = spec.queue_cap
    rank_dtype = st.rank.dtype
    inbox = plan.hop_size(plan.indirection.hops[-1]) * spec.mail_caps[-1]

    def emit_frag(emissions):
        pl, ev = emissions
        return pl, owner_of(pl["target"]).astype(jnp.int32), ev

    def fresh_frags(queue):
        return (queue, _zero_frag(inbox, rank_dtype),
                _zero_frag(spec.spawn_window, rank_dtype))

    def rounds(carry):
        def cond(c):
            return (c[-2] > 0) & (c[-1] < spec.max_rounds)

        def body(c):
            (st, visited, is_ruler, is_sub, perm_pos, (queue, fwd, spawn),
             stats, _, rounds_done) = c
            delivered, dval, queue2, dropped, rst = route_compact(
                plan, spec.mail_caps, [queue, fwd, spawn], qc)
            slots, found = store_lib.slot_of(st, delivered["target"])
            ok = dval & found
            old_succ = st.succ[slots]
            old_rank = st.rank[slots]
            die = is_sub[slots]
            # Alg.1: update succ/rank for every reached element (l.14 and
            # the "still update the values" rule for rulers/terminals)
            st = store_lib.scatter_update(
                st, slots, ok, succ=delivered["ruler"], rank=delivered["weight"])
            visited = visited.at[jnp.where(ok, slots, cap)].set(True, mode="drop")
            # forward the wave (l.13) unless it died on a ruler/terminal
            fwd2 = emit_frag(({"target": old_succ, "ruler": delivered["ruler"],
                               "weight": delivered["weight"] + old_rank},
                              ok & ~die))
            # ruler spawning (l.9-11): one new wave per death
            k = jnp.sum(ok & die).astype(jnp.int32)
            st, visited, is_ruler, perm_pos, spawn_emit, lost = _spawn(
                st, visited, is_ruler, perm, perm_pos, spec.spawn_window, k)
            is_sub = is_sub | is_ruler
            spawn2 = emit_frag(spawn_emit)
            qcount = (jnp.sum(queue2[2]) + jnp.sum(fwd2[2])
                      + jnp.sum(spawn2[2])).astype(jnp.int32)
            pending = plan.psum(qcount + dropped)
            upd = {
                "rounds": jnp.int32(1),
                "chase_msgs": sum(rst["sent"]).astype(jnp.int32),
                "spawn_lost": lost,
                "dropped": dropped,
                "store_miss": jnp.sum(dval & ~found).astype(jnp.int32),
                "max_queue": qcount,
            }
            if plan.telemetry:
                upd["telemetry"] = {"chase": rst["telemetry"],
                                    "queue_hwm": qcount}
            stats = _merge(stats, upd)
            return (st, visited, is_ruler, is_sub, perm_pos,
                    (queue2, fwd2, spawn2), stats, pending, rounds_done + 1)

        return lax.while_loop(cond, body, carry)

    # forced rulers (Alg.1 l.2 findInit — known initial elements) + the
    # random initial ruler set, then the main chase.
    st, visited, is_ruler, forced_emit = _make_rulers(
        st, visited, is_ruler,
        jnp.where(forced, jnp.arange(cap, dtype=jnp.int32), cap), forced)
    (st, visited, is_ruler, rand_emit), consumed, n_rulers = _launch_from_perm(
        st, visited, is_ruler, perm, r_target)
    is_sub = is_sub | is_ruler
    qpl, qd, qv, drop0 = compact_queue(
        [emit_frag(forced_emit), emit_frag(rand_emit)], qc)
    stats = _merge(stats, {
        "dropped": drop0,
        "rulers": n_rulers + jnp.sum(forced).astype(jnp.int32)})
    pend0 = plan.psum(jnp.sum(qv).astype(jnp.int32))
    carry = (st, visited, is_ruler, is_sub, consumed,
             fresh_frags((qpl, qd, qv)), stats, pend0, jnp.int32(0))
    carry = rounds(carry)

    # restart loop: cover stragglers (forward-chasing deadlock or spawn-
    # window losses — rare; see DESIGN.md). New rulers from the unvisited
    # pool; the drained fragments are folded into the fresh queue.
    def uncovered_of(c):
        st, visited = c[0], c[1]
        return plan.psum(jnp.sum(st.valid & ~visited).astype(jnp.int32))

    def r_cond(c):
        return (c[1] > 0) & (c[2] < spec.max_restarts)

    def r_body(c):
        carry, _, restarts = c
        (st, visited, is_ruler, is_sub, perm_pos, (queue, fwd, spawn),
         stats, _, rd) = carry
        (st, visited, is_ruler, emit), _, n1 = _launch_from_perm(
            st, visited, is_ruler, perm, r_target)
        is_sub = is_sub | is_ruler
        qpl, qd, qv, drop1 = compact_queue(
            [queue, fwd, spawn, emit_frag(emit)], qc)
        stats = _merge(stats, {"dropped": drop1, "rulers": n1,
                               "restarts": jnp.int32(1)})
        pend = plan.psum(jnp.sum(qv).astype(jnp.int32))
        carry = rounds((st, visited, is_ruler, is_sub, perm_pos,
                        fresh_frags((qpl, qd, qv)), stats, pend, rd))
        return carry, uncovered_of(carry), restarts + 1

    carry, uncovered, _ = lax.while_loop(
        r_cond, r_body, (carry, uncovered_of(carry), jnp.int32(0)))
    (st, visited, is_ruler, is_sub, perm_pos, _, stats, _, _) = carry
    stats = _merge(stats, {"undelivered": uncovered})
    return st, is_sub, stats


def flip_direction(plan: MeshPlan, spec: LevelSpec, owner_of, st, is_term0,
                   stats):
    """Direction flip (paper §2.5): convert initial-ranking into
    sink(terminal)-ranking. Terminals report (their id, list length) to
    the initial element's owner; every element then asks its initial
    (requests aggregated per PE) and sets
      succ <- terminal,  rank <- total - rank.

    Applied at every recursion level: the level's chase+propagation
    produces initial-ranking, while the parent (and the user) need
    sink-ranking. At the top level this *is* the paper's reversal-
    avoiding postprocess, costing O(#lists * p) aggregated messages.
    """
    cap = st.cap
    gid = st.ids
    term_of = jnp.zeros(cap, jnp.int32)
    total_of = jnp.zeros_like(st.rank)
    have = jnp.zeros(cap, jnp.bool_)

    payload = {"target": st.succ, "term": gid, "total": st.rank}
    dest = owner_of(st.succ).astype(jnp.int32)

    def deliver(carry, delivered, dval):
        term_of, total_of, have = carry
        slots, found = store_lib.slot_of(st, delivered["target"])
        ok = dval & found
        idx = jnp.where(ok, slots, cap)
        term_of = term_of.at[idx].set(delivered["term"], mode="drop")
        total_of = total_of.at[idx].set(delivered["total"], mode="drop")
        have = have.at[idx].set(True, mode="drop")
        return term_of, total_of, have

    mail = tuple(max(c, 8) for c in spec.mail_caps)
    (term_of, total_of, have), pending, msgs, rtele = route_until_done(
        plan, mail, payload, dest, is_term0, deliver,
        (term_of, total_of, have))

    def lookup_fn(gids, valid):
        slots, found = store_lib.slot_of(st, gids)
        ok = found & valid & have[slots]
        return {"term": jnp.where(ok, term_of[slots], gids),
                "total": jnp.where(ok, total_of[slots],
                                   jnp.zeros_like(total_of[slots])),
                "found": ok}

    resp, answered, gst = gather_until_done(
        plan, st.succ, st.valid, owner_of, lookup_fn,
        spec.gather_req_cap, spec.gather_resp_cap, dedup=True)
    upd = answered & resp["found"]
    out = st.replace(succ=jnp.where(upd, resp["term"], st.succ),
                     rank=jnp.where(upd, resp["total"] - st.rank, st.rank))
    fix = {
        "fixup_msgs": msgs + gst["msgs"],
        "undelivered": pending + gst["undelivered"] +
        plan.psum(jnp.sum(st.valid & ~upd).astype(jnp.int32))}
    if plan.telemetry:
        # the terminal-report leg rides the chase-family mail caps; the
        # initial lookup rides the gather caps.
        fix["telemetry"] = {"chase": rtele, "gather": gst["telemetry"]}
    stats = _merge(stats, fix)
    return out, stats


# --------------------------------------------------------------------------
# recursion driver
# --------------------------------------------------------------------------

def _extract_sub(st, is_sub, cap_sub):
    cap = st.ids.shape[0]
    member = st.valid & is_sub
    score = jnp.where(member, jnp.arange(cap, dtype=jnp.int32), INT_MAX)
    order = jnp.argsort(score)
    take = order[:cap_sub]
    n_sub = jnp.sum(member).astype(jnp.int32)
    sval = jnp.arange(cap_sub, dtype=jnp.int32) < jnp.minimum(n_sub, cap_sub)
    sub = store_lib.Store(
        ids=jnp.where(sval, st.ids[take], INT_MAX),
        succ=jnp.where(sval, st.succ[take], INT_MAX),
        rank=jnp.where(sval, st.rank[take], jnp.zeros_like(st.rank[take])),
        valid=sval,
        dense=False,
    )
    overflow = jnp.maximum(n_sub - cap_sub, 0)
    return sub, take, overflow


def base_level(plan: MeshPlan, cfg: ListRankConfig, spec: LevelSpec,
               owner_of, st, stats):
    """The recursion's base case: pointer doubling (or all-gather)."""
    if cfg.base_case == "allgather":
        st, pst = allgather_solve(plan, st, spec.max_rounds)
    else:
        st, pst = doubling_solve(plan, st, owner_of, spec.gather_req_cap,
                                 spec.gather_resp_cap, spec.max_rounds,
                                 dedup=cfg.dedup_requests)
    upd = {"pd_rounds": pst["pd_rounds"], "pd_msgs": pst["pd_msgs"],
           "undelivered": pst["pd_undelivered"]}
    if plan.telemetry and "telemetry" in pst:
        upd["telemetry"] = {"gather": pst["telemetry"]}
    stats = _merge(stats, upd)
    return st, stats


def descend_level(plan: MeshPlan, cfg: ListRankConfig, spec: LevelSpec,
                  owner_of, st, key, level: int, stats, forced=None):
    """The downward half of one recursion level: chase + subproblem
    extraction. Returns ``(st, sub, take, is_sub, is_term, stats)`` —
    everything :func:`ascend_level` needs to finish the level once the
    subproblem is solved (the tuple is a level-boundary checkpointable
    pytree, see api/resume)."""
    cap = st.ids.shape[0]
    is_term = st.valid & (st.succ == st.ids)
    visited = is_term | ~st.valid
    is_ruler = jnp.zeros(cap, jnp.bool_)
    is_sub = is_term
    if forced is None:
        forced = jnp.zeros(cap, jnp.bool_)
    forced = forced & st.valid & ~is_term

    pe = plan.my_id()
    k_pe = jax.random.fold_in(jax.random.fold_in(key, level), pe)
    perm = jax.random.permutation(k_pe, cap).astype(jnp.int32)
    perm = jnp.concatenate(
        [perm, jnp.full((spec.spawn_window,), cap, jnp.int32)])

    # ruler target: the level's tuned fraction of the live instance,
    # clipped to the static bound derived from the same fraction
    # (spec.ruler_frac comes from tuner.level_plan via build_specs —
    # there is no separate fallback here).
    n_active = jnp.sum(st.valid).astype(jnp.int32)
    r_target = jnp.maximum(jnp.int32(cfg.min_rulers_per_pe),
                           (spec.ruler_frac * n_active).astype(jnp.int32))
    r_target = jnp.minimum(r_target, jnp.int32(spec.r_static))

    st, is_sub, stats = _chase(plan, spec, owner_of, st, visited, is_ruler,
                               is_sub, forced, perm, r_target, stats)

    sub, take, overflow = _extract_sub(st, is_sub, spec.cap_sub)
    n_sub = jnp.sum(sub.valid).astype(jnp.int32)
    upd = {"sub_overflow": overflow, "sub_size": n_sub}
    if plan.telemetry:
        # sub-store occupancy as a fill record: demand (incl. overflow)
        # over the compiled cap_sub — >1 explains a sub escalation.
        upd["telemetry"] = {"sub": tele_lib.store_fill(
            plan.indirection.depth, n_sub + overflow, spec.cap_sub)}
    stats = _merge(stats, upd)
    return st, sub, take, is_sub, is_term, stats


def ascend_level(plan: MeshPlan, cfg: ListRankConfig, spec: LevelSpec,
                 owner_of, st, sub, take, is_sub, is_term, stats,
                 want_sink: bool = True):
    """The upward half of one recursion level: write back the solved
    subproblem, propagate through rulers, flip direction if the caller
    wants sink-ranking."""
    cap = st.ids.shape[0]
    # write back solved sub elements
    idx = jnp.where(sub.valid, take, cap)
    st = st.replace(succ=st.succ.at[idx].set(sub.succ, mode="drop"),
                    rank=st.rank.at[idx].set(sub.rank, mode="drop"))

    # ruler propagation (Alg.1 l.16-19): non-sub elements ask their ruler
    non_sub = st.valid & ~is_sub
    resp, answered, gst = gather_until_done(
        plan, st.succ, non_sub, owner_of,
        lambda g, v: store_lib.lookup(st, g, v),
        spec.gather_req_cap, spec.gather_resp_cap, cfg.dedup_requests)
    upd = answered & resp["found"]
    st = st.replace(succ=jnp.where(upd, resp["succ"], st.succ),
                    rank=jnp.where(upd, st.rank + resp["rank"], st.rank))
    prop = {
        "undelivered": gst["undelivered"] +
        plan.psum(jnp.sum(non_sub & ~upd).astype(jnp.int32)),
        "fixup_msgs": gst["msgs"]}
    if plan.telemetry:
        prop["telemetry"] = {"gather": gst["telemetry"]}
    stats = _merge(stats, prop)

    if want_sink:
        st, stats = flip_direction(plan, spec, owner_of, st, is_term, stats)
    return st, stats


def solve_store(plan: MeshPlan, cfg: ListRankConfig, specs: list[LevelSpec],
                owner_of, st, key, level: int, stats, forced=None,
                want_sink: bool = True):
    """Recursively solve the instance in ``st``.

    Returns sink-ranking (succ -> the self-loop end of each list, rank =
    weighted distance to it) when ``want_sink``; otherwise the raw
    initial-ranking that forward chasing produces (used by the faithful
    Algorithm-1 variant, whose input is the reversed instance).

    Internal recursion always requests sink-ranking: the extracted
    subproblem's self-loop ends are exactly this level's unreached
    initials, which is what ruler propagation composes with.

    The body is exactly ``descend_level`` → recurse → ``ascend_level``
    (``base_level`` at the bottom) — the same stage functions the
    level-resumable driver (api/resume) runs one at a time, so the
    monolithic and staged programs are op-for-op identical."""
    spec = specs[level]

    if spec.base:
        return base_level(plan, cfg, spec, owner_of, st, stats)

    st, sub, take, is_sub, is_term, stats = descend_level(
        plan, cfg, spec, owner_of, st, key, level, stats, forced)

    sub, stats = solve_store(plan, cfg, specs, owner_of, sub, key, level + 1,
                             stats, want_sink=True)

    return ascend_level(plan, cfg, spec, owner_of, st, sub, take, is_sub,
                        is_term, stats, want_sink)
