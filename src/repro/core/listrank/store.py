"""Element stores: addressable per-PE views of a (sub-)instance.

Level 0 of the recursion owns a *dense* contiguous block of element ids
(direct indexing); deeper SRS levels operate on *sparse* stores — the
extracted ruler subproblem whose global ids are scattered — addressed
via binary search over the per-PE sorted id array.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.tree_util.register_dataclass,
         data_fields=("ids", "succ", "rank", "valid"),
         meta_fields=("dense",))
@dataclasses.dataclass
class Store:
    """Per-PE view of a (sub-)instance.

    ids   (cap,) int32  global element ids (ascending among valid slots;
                        invalid slots hold INT32_MAX for sparse stores)
    succ  (cap,) int32  current successor (global id)
    rank  (cap,)        current weight/rank
    valid (cap,) bool   slot occupancy
    dense bool          static: ids are the contiguous range base..base+cap
    """
    ids: jax.Array
    succ: jax.Array
    rank: jax.Array
    valid: jax.Array
    dense: bool = False

    @property
    def cap(self) -> int:
        return self.ids.shape[0]

    def replace(self, **kw) -> "Store":
        return dataclasses.replace(self, **kw)


def make_dense_store(succ: jax.Array, rank: jax.Array, active: jax.Array,
                     base: jax.Array) -> Store:
    m = succ.shape[0]
    ids = base + jnp.arange(m, dtype=jnp.int32)
    return Store(ids=ids, succ=succ, rank=rank, valid=active, dense=True)


def slot_of(store: Store, gids: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Map global ids to local slots. Returns (slot, found)."""
    cap = store.cap
    if store.dense:
        slot = (gids - store.ids[0]).astype(jnp.int32)
        inr = (slot >= 0) & (slot < cap)
        slot = jnp.clip(slot, 0, cap - 1)
        return slot, inr & store.valid[slot]
    # sparse: ids ascending among valid slots; invalid slots hold INT32_MAX
    slot = jnp.searchsorted(store.ids, gids).astype(jnp.int32)
    slot = jnp.clip(slot, 0, cap - 1)
    found = (store.ids[slot] == gids) & store.valid[slot]
    return slot, found


def lookup(store: Store, gids: jax.Array, valid: jax.Array,
           packed: bool = True) -> dict[str, jax.Array]:
    """Owner-side lookup for remote_gather: (succ, rank) at global ids.

    ``packed`` takes the wire-word fast path: (succ, rank) are stacked
    into one (cap, 2) int32 table so each query is a single row gather
    instead of one gather per field — the owner-side mirror of the
    exchange layer's packed wire format. The table build costs 2*cap
    sequential writes per call; all callers query cap-sized batches
    (pointer doubling, ruler propagation), so it trades those writes
    for halving the random-access gathers — the right trade on an
    accelerator. Bit-identical to the unpacked path (rank travels as
    its exact bit pattern).
    """
    slot, found = slot_of(store, gids)
    ok = found & valid
    if packed:
        from repro.core.listrank import exchange as exchange_lib
        tbl = jnp.stack(
            [store.succ, exchange_lib.to_wire_word(store.rank)], axis=1)
        rows = tbl[slot]
        succ = rows[:, 0]
        rank = exchange_lib.from_wire_word(rows[:, 1], store.rank.dtype)
    else:
        succ = store.succ[slot]
        rank = store.rank[slot]
    return {
        "succ": jnp.where(ok, succ, gids),
        "rank": jnp.where(ok, rank, jnp.zeros_like(rank)),
        "found": ok,
    }


def scatter_update(store: Store, slots: jax.Array, upd_valid: jax.Array,
                   **fields: jax.Array) -> Store:
    """Set fields at slots (masked). Returns the updated store."""
    cap = store.cap
    idx = jnp.where(upd_valid, slots, cap)
    kw = {}
    for k, v in fields.items():
        kw[k] = getattr(store, k).at[idx].set(v, mode="drop")
    return store.replace(**kw)
