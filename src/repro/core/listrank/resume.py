"""Level-resumable solver: the SRS recursion as an explicit state machine.

The monolithic per-PE program (``api._solve_sharded``) is a composition
of stage bodies (``srs.base_level`` / ``descend_level`` /
``ascend_level`` plus prep/restore) under one jit. This module runs the
*same* bodies one stage at a time, materializing the state at every
level boundary as a checkpointable pytree:

    prep -> descend@0 .. descend@L-1 -> base@L -> ascend@L-1 .. ascend@0 -> post
    prep -> pd@0 -> post                                   (plain doubling)

Because the staged program is built from the exact functions the
monolithic program composes, a straight-through staged solve is
op-for-op identical to the monolithic one — the golden bit-identity
pins (tests/golden) hold for both by construction.

What the explicit boundary state buys (DESIGN.md §11):

- **level resume**: a fatal capacity overflow at stage k re-runs *only*
  stage k with that capacity family escalated for levels >= k
  (``tuner.escalate_levels``); completed levels' scales — and therefore
  the checkpointed store shapes — are untouched. The old driver
  restarted the whole solve from scratch.
- **checkpoint/restart**: a :class:`~repro.runtime.fault_tolerance.
  SolveSupervisor` checkpoints the boundary state (atomic keep-k,
  async); SIGTERM/SIGINT preemption writes a blocking checkpoint and
  raises ``Preempted``; a restarted driver restores and continues from
  the boundary. Checkpoints hold *global* (host-gathered) arrays plus a
  manifest meta, so the restore is elastic: a mesh-backend checkpoint
  resumes under simshard and vice versa, bit-identically.
- **deterministic fault injection** (:mod:`.faults`): PE loss,
  corrupted state planes, forced overflows and preemption fire at named
  stage boundaries, driving the recovery paths in-process under the
  simshard backend for any p.

The boundary state is a dict pytree; every leaf is block-sharded over
the PE axes on axis 0 (per-PE stats ride as (1,)-per-PE slices):

    stores:   (store_0, ..., store_j)   recursion store stack
    takes:    per descended level, the sub-extraction slot map
    is_subs:  per descended level, the sub-membership mask
    is_terms: per descended level, the level's terminal mask
    stats:    per-PE partial stat counters (psum'd once, in post)
    forced:   [srs only, until descend@0] forced-ruler mask
    rep/aux:  [local_contraction only] restoration inputs (§2.3)
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.listrank import faults as faults_lib
from repro.core.listrank import introspect
from repro.core.listrank import local as local_lib
from repro.core.listrank import srs as srs_lib
from repro.core.listrank import store as store_lib
from repro.core.listrank import transport as transport_lib
from repro.core.listrank import tuner
from repro.core.listrank.config import ListRankConfig
from repro.core.listrank.doubling import doubling_solve
from repro.core.listrank.srs import zero_stats, _merge
from repro.obs import telemetry as tele_lib
from repro.obs import trace as trace_lib
from repro.runtime.fault_tolerance import Preempted

#: stat keys whose nonzero value means the attempt is unusable.
FATAL_KEYS = ("dropped", "sub_overflow", "store_miss", "undelivered")

#: capacity family -> the fatal stat the driver synthesizes for an
#: injected overflow of that family (the inverse of tuner.FAMILY_OF
#: restricted to the capacity-exclusive solver families).
FAMILY_STAT = {"chase": "dropped", "sub": "sub_overflow",
               "gather": "undelivered"}


class SolveExhausted(RuntimeError):
    """The retry/escalation budget ran out.

    Structured for assertions: ``attempts`` (total), ``scales_log``
    (the full per-attempt escalation path, as rendered in host_stats),
    ``fatal`` (fatal stat -> its count in the failing attempt),
    ``families`` (the capacity families those stats implicate), and
    ``stats`` (the failing attempt's full host counter dict).
    """

    def __init__(self, attempts: int, scales_log, fatal: dict, stats=None):
        self.attempts = int(attempts)
        self.scales_log = tuple(scales_log)
        self.fatal = {k: int(v) for k, v in fatal.items()}
        self.families = tuple(sorted({
            f for k, v in self.fatal.items() if v
            for f in tuner.FAMILY_OF.get(k, ())}))
        self.stats = dict(stats or {})
        super().__init__(
            f"list ranking did not complete after {self.attempts} attempts")

    def __str__(self) -> str:
        """Readable exhaustion report: the per-attempt escalation path
        (each entry is a ``tuner.format_scales`` rendering, ``@Lk`` for
        level-targeted escalations) and the fatal stats with the
        capacity families they implicate."""
        lines = [f"list ranking did not complete after {self.attempts} "
                 f"attempts (capacity escalation exhausted)",
                 "  escalation path:"]
        for i, entry in enumerate(self.scales_log, start=1):
            lines.append(f"    attempt {i}: {entry}")
        lines.append("  fatal stats of the failing attempt:")
        for key, count in sorted(self.fatal.items()):
            if not count:
                continue
            fams = tuner.FAMILY_OF.get(key, ())
            fam_s = (f" -> escalates {', '.join(fams)}" if fams
                     else " (no capacity family)")
            lines.append(f"    {key}={count}{fam_s}")
        if not any(self.fatal.values()):
            lines.append("    (none recorded)")
        return "\n".join(lines)


# --------------------------------------------------------------------------
# the schedule
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Stage:
    """One stage of the staged solve. ``level`` is the recursion level
    for descend/base/ascend (pd pins 0); -1 for prep/post."""
    kind: str      # prep | descend | base | ascend | pd | post
    level: int

    @property
    def label(self) -> str:
        return self.kind if self.level < 0 else f"{self.kind}@{self.level}"


def schedule_for(cfg: ListRankConfig) -> tuple[Stage, ...]:
    """The stage schedule for a resolved config (algorithm != auto)."""
    if cfg.algorithm == "doubling":
        return (Stage("prep", -1), Stage("pd", 0), Stage("post", -1))
    L = cfg.srs_rounds
    out = [Stage("prep", -1)]
    out += [Stage("descend", k) for k in range(L)]
    out += [Stage("base", L)]
    out += [Stage("ascend", k) for k in reversed(range(L))]
    out += [Stage("post", -1)]
    return tuple(out)


def _stage_specs(stage: Stage, specs) -> tuple:
    """The LevelSpecs a stage body closes over (part of the jit key)."""
    if stage.kind in ("prep", "post"):
        return (specs[0],)
    if stage.kind == "pd":
        return (specs[0], specs[-1])
    if stage.kind == "base":
        return (specs[-1],)
    return (specs[stage.level],)


# --------------------------------------------------------------------------
# stage bodies (per-PE; run under device_run on either backend)
# --------------------------------------------------------------------------

def _owner_fn(m: int):
    def owner_of(g):
        return g // m
    return owner_of


def _stats_out(stats):
    """Per-PE scalar stats -> (1,)-per-PE leaves (shardable on axis 0)."""
    return {k: jnp.reshape(v, (1,)) for k, v in stats.items()}


def _stats_in(stats):
    return {k: jnp.reshape(v, ()) for k, v in stats.items()}


def _tele_seed(stats, plan):
    """Seed the per-stage device telemetry record (cfg.telemetry): a
    fresh ``stage_zero`` per stage so telemetry is attributed per stage
    instead of accumulating through the boundary state. The record is
    popped again by :func:`_tele_pop` before the stats re-enter the
    committed boundary (``boundary_template`` is unchanged — telemetry
    never reaches a checkpoint)."""
    if plan.telemetry:
        stats["telemetry"] = tele_lib.stage_zero(plan.indirection.depth)
    return stats


def _tele_pop(stats, plan):
    """Split a stage's stats into (plain stats, per-PE telemetry-out).
    The telemetry leaves gain a leading (1,)-per-PE axis so the same
    block sharding as the stats applies."""
    if not plan.telemetry:
        return stats, None
    tele = stats.pop("telemetry")
    return stats, jax.tree.map(lambda v: v[None], tele)


def _prep_body(succ, rank, *, plan, cfg, spec0, m):
    """Everything before the recursion: contraction, store build, and
    (faithful Algorithm 1 only) the reversal preprocessing."""
    from repro.core.listrank import api as api_lib
    pe = plan.my_id().astype(jnp.int32)
    base = pe * m
    gid = base + jnp.arange(m, dtype=jnp.int32)
    stats = _tele_seed(zero_stats(), plan)
    owner_of = _owner_fn(m)

    if cfg.local_contraction:
        succ_w, rank_w, rep, aux = local_lib.contract(
            succ, rank, base, m, cfg.use_pallas)
        active = rep
    else:
        rep, aux = None, None
        succ_w, rank_w = succ, rank
        active = jnp.ones(m, jnp.bool_)

    is_term0 = active & (succ_w == gid)
    st = store_lib.make_dense_store(succ_w, rank_w, active, base)

    state = {}
    if cfg.algorithm == "srs":
        if cfg.avoid_reversal:
            # solve_store(forced=None) builds an all-false mask itself;
            # carrying the zeros explicitly is bit-identical.
            state["forced"] = jnp.zeros(m, jnp.bool_)
        else:
            st, stats = api_lib._reverse_instance(plan, spec0, owner_of, st,
                                                  stats)
            state["forced"] = is_term0
    state["stores"] = (st,)
    state["takes"] = ()
    state["is_subs"] = ()
    state["is_terms"] = ()
    if cfg.local_contraction:
        state["rep"] = rep
        state["aux"] = aux
    stats, tele = _tele_pop(stats, plan)
    state["stats"] = _stats_out(stats)
    if tele is not None:
        state["_telemetry"] = tele
    return state


def _descend_body(state, seed, *, plan, cfg, spec, level, m):
    owner_of = _owner_fn(m)
    key = jax.random.PRNGKey(seed)
    stats = _tele_seed(_stats_in(state["stats"]), plan)
    st = state["stores"][-1]
    forced = state.get("forced") if level == 0 else None
    st, sub, take, is_sub, is_term, stats = srs_lib.descend_level(
        plan, cfg, spec, owner_of, st, key, level, stats, forced)
    out = {k: v for k, v in state.items() if k != "forced"}
    out["stores"] = state["stores"][:-1] + (st, sub)
    out["takes"] = state["takes"] + (take,)
    out["is_subs"] = state["is_subs"] + (is_sub,)
    out["is_terms"] = state["is_terms"] + (is_term,)
    stats, tele = _tele_pop(stats, plan)
    out["stats"] = _stats_out(stats)
    if tele is not None:
        out["_telemetry"] = tele
    return out


def _base_body(state, *, plan, cfg, spec, m):
    stats = _tele_seed(_stats_in(state["stats"]), plan)
    st, stats = srs_lib.base_level(plan, cfg, spec, _owner_fn(m),
                                   state["stores"][-1], stats)
    out = dict(state)
    out["stores"] = state["stores"][:-1] + (st,)
    stats, tele = _tele_pop(stats, plan)
    out["stats"] = _stats_out(stats)
    if tele is not None:
        out["_telemetry"] = tele
    return out


def _ascend_body(state, *, plan, cfg, spec, level, m, want_sink):
    stats = _tele_seed(_stats_in(state["stats"]), plan)
    st, sub = state["stores"][-2], state["stores"][-1]
    st, stats = srs_lib.ascend_level(
        plan, cfg, spec, _owner_fn(m), st, sub,
        state["takes"][-1], state["is_subs"][-1], state["is_terms"][-1],
        stats, want_sink)
    out = dict(state)
    out["stores"] = state["stores"][:-2] + (st,)
    out["takes"] = state["takes"][:-1]
    out["is_subs"] = state["is_subs"][:-1]
    out["is_terms"] = state["is_terms"][:-1]
    stats, tele = _tele_pop(stats, plan)
    out["stats"] = _stats_out(stats)
    if tele is not None:
        out["_telemetry"] = tele
    return out


def _pd_body(state, *, plan, cfg, spec0, spec_base, m):
    stats = _tele_seed(_stats_in(state["stats"]), plan)
    st, pst = doubling_solve(plan, state["stores"][-1], _owner_fn(m),
                             spec0.gather_req_cap, spec0.gather_resp_cap,
                             spec_base.max_rounds, cfg.dedup_requests)
    upd = {"pd_rounds": pst["pd_rounds"],
           "pd_msgs": pst["pd_msgs"],
           "undelivered": pst["pd_undelivered"]}
    if plan.telemetry:
        # PD requests ride the gather-family mailboxes (req/resp caps).
        upd["telemetry"] = {"gather": pst["telemetry"]}
    stats = _merge(stats, upd)
    out = dict(state)
    out["stores"] = state["stores"][:-1] + (st,)
    stats, tele = _tele_pop(stats, plan)
    out["stats"] = _stats_out(stats)
    if tele is not None:
        out["_telemetry"] = tele
    return out


def _post_body(state, succ, rank, *, plan, cfg, spec0, m):
    """Everything after the recursion: §2.3 restoration and the final
    stat reduction (the one psum over the carried per-PE partials)."""
    from repro.core.listrank import api as api_lib
    pe = plan.my_id().astype(jnp.int32)
    base = pe * m
    stats = _tele_seed(_stats_in(state["stats"]), plan)
    st = state["stores"][0]
    if cfg.local_contraction:
        succ_f, rank_f, stats = api_lib._restore_local(
            plan, spec0, _owner_fn(m), st, state["aux"], state["rep"],
            succ, rank, base, stats)
    else:
        succ_f, rank_f = st.succ, st.rank
    # telemetry leaves stay per-PE: the one stat psum below must not
    # grow any collectives when telemetry is on (pinned by the
    # transport-audit count tests), so pop before reducing.
    stats, tele = _tele_pop(stats, plan)
    stats = {k: plan.psum(v) for k, v in stats.items()}
    if tele is not None:
        return succ_f, rank_f, stats, tele
    return succ_f, rank_f, stats


@functools.lru_cache(maxsize=512)
def _jitted_stage(mesh, plan, cfg, stage: Stage, key_specs, m):
    """Jit one stage for one backend; keyed exactly on what the traced
    program depends on (the stage's own LevelSpecs, not the full spec
    tuple — escalating level k never retraces completed stages)."""
    sh = P(plan.pe_axes)
    rep = P()
    if stage.kind == "prep":
        fn = functools.partial(_prep_body, plan=plan, cfg=cfg,
                               spec0=key_specs[0], m=m)
        in_specs, out_specs = (sh, sh), sh
    elif stage.kind == "descend":
        fn = functools.partial(_descend_body, plan=plan, cfg=cfg,
                               spec=key_specs[0], level=stage.level, m=m)
        in_specs, out_specs = (sh, rep), sh
    elif stage.kind == "base":
        fn = functools.partial(_base_body, plan=plan, cfg=cfg,
                               spec=key_specs[0], m=m)
        in_specs, out_specs = (sh,), sh
    elif stage.kind == "ascend":
        want_sink = stage.level > 0 or cfg.avoid_reversal
        fn = functools.partial(_ascend_body, plan=plan, cfg=cfg,
                               spec=key_specs[0], level=stage.level, m=m,
                               want_sink=want_sink)
        in_specs, out_specs = (sh,), sh
    elif stage.kind == "pd":
        fn = functools.partial(_pd_body, plan=plan, cfg=cfg,
                               spec0=key_specs[0], spec_base=key_specs[1],
                               m=m)
        in_specs, out_specs = (sh,), sh
    elif stage.kind == "post":
        fn = functools.partial(_post_body, plan=plan, cfg=cfg,
                               spec0=key_specs[0], m=m)
        in_specs = (sh, sh, sh)
        # telemetry-on: the per-PE telemetry record is a 4th output
        # (prefix spec sh covers the whole subtree).
        out_specs = (sh, sh, rep, sh) if plan.telemetry else (sh, sh, rep)
    else:
        raise ValueError(f"unknown stage kind {stage.kind!r}")
    return transport_lib.device_run(mesh, plan.pe_axes, fn,
                                    in_specs=in_specs, out_specs=out_specs)


# --------------------------------------------------------------------------
# boundary-state templates (for elastic checkpoint restore)
# --------------------------------------------------------------------------

def boundary_template(sched, idx: int, cfg: ListRankConfig, specs, m: int,
                      p: int, weight_dtype):
    """The abstract (ShapeDtypeStruct) boundary-state pytree after the
    first ``idx`` stages of ``sched`` — global (host-gathered) shapes,
    so a checkpoint written by either backend restores into it."""
    if idx < 1:
        raise ValueError("no boundary state before the prep stage")
    wdt = jnp.dtype(weight_dtype)
    caps = [m]                      # store-capacity stack
    take_caps: list[int] = []
    has_forced = cfg.algorithm != "doubling"
    for stage in sched[1:idx]:
        if stage.kind == "descend":
            take_caps.append(specs[stage.level].cap_sub)
            caps.append(specs[stage.level].cap_sub)
            if stage.level == 0:
                has_forced = False
        elif stage.kind == "ascend":
            caps.pop()
            take_caps.pop()
        # base / pd leave the structure unchanged

    def arr(cap, dtype):
        return jax.ShapeDtypeStruct((p * cap,), dtype)

    def store_t(j, cap):
        return store_lib.Store(ids=arr(cap, jnp.int32),
                               succ=arr(cap, jnp.int32),
                               rank=arr(cap, wdt),
                               valid=arr(cap, jnp.bool_),
                               dense=(j == 0))

    state = {}
    if has_forced:
        state["forced"] = arr(m, jnp.bool_)
    state["stores"] = tuple(store_t(j, c) for j, c in enumerate(caps))
    state["takes"] = tuple(arr(c, jnp.int32) for c in take_caps)
    # the level-k masks cover the store that was live when level k
    # descended: caps[k] for every descended-but-not-ascended level.
    state["is_subs"] = tuple(arr(c, jnp.bool_) for c in caps[:-1]) \
        if take_caps else ()
    state["is_terms"] = state["is_subs"]
    if cfg.local_contraction:
        state["rep"] = arr(m, jnp.bool_)
        state["aux"] = {"S": arr(m, jnp.int32), "D": arr(m, wdt),
                        "stop_is_term": arr(m, jnp.bool_)}
    state["stats"] = {k: jax.ShapeDtypeStruct((p,), jnp.int32)
                      for k in zero_stats()}
    return state


def state_shardings(mesh, plan, like):
    """Block-sharded placement for every boundary-state leaf (None on a
    SimMesh — the simshard runner folds the PE axis itself)."""
    if transport_lib.is_sim(mesh):
        return None
    sh = NamedSharding(mesh, P(plan.pe_axes))
    return jax.tree.map(lambda _: sh, like)


def solve_fingerprint(succ, rank, n: int, p: int, seed: int,
                      cfg: ListRankConfig) -> str:
    """Identity of a solve for restore validation: instance bytes plus
    the backend-independent config. A checkpoint restores only into the
    same logical solve — on either backend (elastic), since backend and
    kernel toggles never change the computed bits."""
    h = hashlib.sha256()
    h.update(np.asarray(jax.device_get(succ)).astype(np.int32).tobytes())
    h.update(np.asarray(jax.device_get(rank)).tobytes())
    key = (n, p, int(seed),
           cfg.with_(backend="auto", use_pallas=False, use_pallas_pack=False))
    h.update(repr(key).encode())
    return h.hexdigest()


# --------------------------------------------------------------------------
# host-side state validation + corruption
# --------------------------------------------------------------------------

def validate_state(state, n: int) -> None:
    """Host-side invariant check of a boundary state: every valid store
    slot must hold ids/succ inside [0, n). Catches the ``corrupt``
    injection's sentinel (and real bit-rot) before it is checkpointed
    or consumed by the next stage."""
    for j, st in enumerate(state["stores"]):
        valid = np.asarray(jax.device_get(st.valid))
        for plane in ("ids", "succ"):
            v = np.asarray(jax.device_get(getattr(st, plane)))
            bad = valid & ((v < 0) | (v >= n))
            if bad.any():
                k = int(np.argmax(bad))
                raise faults_lib.CorruptedState(
                    f"store {j} plane {plane!r}: invalid global id "
                    f"{int(v[k])} at slot {k} (n={n})")


def _apply_corruption(state, spec: faults_lib.FaultSpec, mesh, plan, m: int):
    """Scribble the corrupt sentinel over PE ``spec.pe``'s slice of the
    top store's ``spec.plane`` — a lost/garbled mailbox plane."""
    st = state["stores"][0]
    leaf = np.asarray(jax.device_get(getattr(st, spec.plane))).copy()
    pe = spec.pe % max(plan.p, 1)
    leaf[pe * m:(pe + 1) * m] = faults_lib.CORRUPT_SENTINEL
    leaf_d = transport_lib.put_sharded(mesh, plan.pe_axes, jnp.asarray(leaf))
    out = dict(state)
    out["stores"] = (st.replace(**{spec.plane: leaf_d}),) \
        + state["stores"][1:]
    return out


def _fatal_totals(stats) -> dict:
    """Global fatal-stat totals from a boundary state's per-PE stats (or
    post's already-reduced dict)."""
    return {k: int(np.sum(np.asarray(jax.device_get(stats[k]))))
            for k in FATAL_KEYS}


# --------------------------------------------------------------------------
# the driver
# --------------------------------------------------------------------------

def run_staged(succ_d, rank_d, *, mesh, plan, cfg: ListRankConfig, m: int,
               n: int, seed: int, build_level_specs, max_retries: int = 3,
               supervisor=None, inject=None, stage_counters: bool = False,
               initial_scales=None, tracer=None):
    """Run the staged solve to completion. Returns (succ, rank, stats).

    ``build_level_specs(level_scales) -> tuple[LevelSpec]`` is the
    host-side capacity derivation (api.build_specs closed over the
    instance parameters). ``supervisor`` (a
    :class:`~repro.runtime.fault_tolerance.SolveSupervisor`) enables
    checkpoint/restart + preemption; ``inject`` (a
    :class:`~repro.core.listrank.faults.FaultInjector`, FaultSpec, or
    sequence of FaultSpecs) drives the recovery paths deterministically;
    ``stage_counters`` records each executed stage's traced collective
    counts in ``host_stats["stage_collectives"]``; ``tracer`` (a
    :class:`repro.obs.Tracer`) records the flight-recorder span tree —
    one ``stage`` span per schedule slot with one nested
    ``stage-attempt`` span per execution, each annotated with the
    §2.6 predicted time and the stage's static collective footprint.
    The tracer is host-side only: it never enters a jit key or a traced
    body, so the executed programs are bit-identical with it on or off.
    """
    p = plan.p
    wdt = rank_d.dtype
    sched = schedule_for(cfg)
    n_levels = cfg.srs_rounds + 1
    tr = trace_lib.ensure(tracer)
    injector = inject
    if injector is not None and not isinstance(injector,
                                               faults_lib.FaultInjector):
        injector = faults_lib.FaultInjector(injector)

    level_scales = tuner.normalize_level_scales(
        initial_scales if initial_scales is not None
        else tuner.CapacityScales(), n_levels)
    attempts = 1
    scales_log = [tuner.format_scales(level_scales[0])]
    stage_log: list[str] = []
    injected_log: list[str] = []
    stage_collectives: list[tuple] = []
    tele_records: list[tele_lib.StageRecord] = []
    crashes = 0
    if supervisor is not None:
        supervisor.tracer = tr

    fp = solve_fingerprint(succ_d, rank_d, n, p, seed, cfg)

    # one stage span per schedule slot stays open across its overflow
    # retries (attempts nest under it); footprints are static per jitted
    # runner, so they are counted once and cached by runner identity
    # (runners are pinned alive by the _jitted_stage lru_cache).
    stage_span, stage_span_idx, stage_attempt = None, -1, 0
    footprint_cache: dict[int, dict] = {}

    def close_stage_span(**kw):
        nonlocal stage_span
        if stage_span is not None:
            tr.end(stage_span, **kw)
            stage_span = None

    def stage_prediction(runner, args):
        """(annotations dict) — static §2.6 prediction of one stage
        execution from its jaxpr collective footprint. Trace-only: no
        device code runs, nothing about the solve changes."""
        from repro.obs import cost as cost_lib
        key = id(runner)
        if key not in footprint_cache:
            footprint_cache[key] = introspect.collective_footprint(
                runner, *args)
        fprint = footprint_cache[key]
        pred = cost_lib.predict_stage(fprint, plan, cfg.machine,
                                      transport_lib.is_sim(mesh))
        count, nbytes = cost_lib.total_collectives(fprint)
        if transport_lib.is_sim(mesh):
            nbytes //= max(p, 1)  # marker operands carry the vPE axis
        return {"predicted_s": pred["total_s"],
                "predicted_startup_s": pred["startup_s"],
                "predicted_volume_s": pred["volume_s"],
                "collective_count": count, "payload_bytes": nbytes,
                "footprint": cost_lib.footprint_summary(fprint)}

    def make_meta(idx):
        return {"format": 1, "idx": idx, "fingerprint": fp, "n": n, "p": p,
                "m": m, "algorithm": cfg.algorithm, "attempts": attempts,
                "scales_log": list(scales_log),
                "scales": [dataclasses.asdict(s) for s in level_scales],
                "weight_dtype": str(wdt)}

    def try_restore():
        """(state, idx, prev_fatal) from the supervisor's latest valid
        checkpoint, or None."""
        if supervisor is None:
            return None
        # drain any in-flight async boundary write: the latest committed
        # boundary must be durable (and its failure surfaced) before we
        # decide where to resume from.
        supervisor.ckpt.wait()
        meta = supervisor.latest_meta()
        if not meta or meta.get("fingerprint") != fp:
            return None
        nonlocal level_scales, attempts, scales_log
        level_scales = tuple(tuner.CapacityScales(**d)
                             for d in meta["scales"])
        attempts = int(meta["attempts"])
        scales_log = list(meta["scales_log"])
        specs = build_level_specs(level_scales)
        like = boundary_template(sched, meta["idx"], cfg, specs, m, p,
                                 jnp.dtype(meta["weight_dtype"]))
        state, _ = supervisor.restore(like, state_shardings(mesh, plan, like))
        supervisor.stats["resumed_from"] = int(meta["idx"])
        return state, int(meta["idx"]), _fatal_totals(state["stats"])

    state, idx = None, 0
    prev_fatal = {k: 0 for k in FATAL_KEYS}
    restored = try_restore()
    if restored is not None:
        state, idx, prev_fatal = restored

    while idx < len(sched):
        stage = sched[idx]
        if supervisor is not None and supervisor.preempted:
            if state is not None:
                supervisor.boundary(idx, state, make_meta(idx),
                                    blocking=True)
            supervisor.stats["preempted"] += 1
            raise Preempted(
                f"preempted at stage boundary {idx}/{len(sched)}")
        if stage_span_idx != idx:
            close_stage_span(outcome="abandoned")  # crash rewound idx
            stage_span = tr.begin(stage.label, cat="stage",
                                  stage=stage.kind, level=stage.level,
                                  schedule_idx=idx)
            stage_span_idx, stage_attempt = idx, 0
        stage_attempt += 1
        specs = build_level_specs(level_scales)
        att = tr.begin(f"{stage.label}#{stage_attempt}", cat="stage-attempt",
                       stage=stage.label, level=stage.level,
                       attempt=stage_attempt,
                       scales=tuner.format_scales(
                           level_scales[max(stage.level, 0)]))
        try:
            if injector is not None:
                injector.crash_before(stage.kind, stage.level)
            runner = _jitted_stage(mesh, plan, cfg, stage,
                                   _stage_specs(stage, specs), m)
            args = _stage_args(stage, state, succ_d, rank_d, seed)
            t0 = time.time()
            out = runner(*args)
            jax.block_until_ready(jax.tree.leaves(out))
            dt = time.time() - t0
            if stage.kind == "post":
                out_state, fatal_src = state, out[2]
            else:
                out_state, fatal_src = out, out["stats"]
            if injector is not None:
                cspec = injector.corrupt_after(stage.kind, stage.level)
                if cspec is not None:
                    injected_log.append(f"corrupt:{stage.label}")
                    tr.instant(f"corrupt:{stage.label}", cat="fault",
                               stage=stage.label, plane=cspec.plane)
                    if stage.kind != "post":
                        out_state = out = _apply_corruption(
                            out, cspec, mesh, plan, m)
                validate_state(out_state, n)
        except (faults_lib.InjectedFault, faults_lib.CorruptedState) as e:
            crashes += 1
            if isinstance(e, faults_lib.InjectedFault):
                injected_log.append(f"pe_loss:{stage.label}")
                tr.instant(f"pe_loss:{stage.label}", cat="fault",
                           stage=stage.label)
            stage_log.append(f"{stage.label}!{type(e).__name__}")
            tr.end(att, outcome=type(e).__name__)
            close_stage_span(outcome="crashed")
            budget_ok = (supervisor.should_retry() if supervisor is not None
                         else crashes <= max_retries)
            if not budget_ok:
                raise
            restored = try_restore()
            if restored is not None:
                state, idx, prev_fatal = restored
            else:
                state, idx = None, 0
                prev_fatal = {k: 0 for k in FATAL_KEYS}
            stage_span_idx = -1  # reopen a fresh stage span after rewind
            continue

        if tr.enabled:
            att.annotate(**stage_prediction(runner, args))
        fatal = _fatal_totals(fatal_src)
        delta = {k: fatal[k] - prev_fatal[k] for k in FATAL_KEYS}
        fam = (injector.overflow_after(stage.kind, stage.level)
               if injector is not None else None)
        if fam is not None:
            injected_log.append(f"overflow:{fam}:{stage.label}")
            tr.instant(f"overflow:{fam}:{stage.label}", cat="fault",
                       stage=stage.label, family=fam)
        if any(v > 0 for v in delta.values()) or fam is not None:
            # the failed attempt's output is discarded: the committed
            # boundary state (end of the previous stage) is the resume
            # point, with only the implicated families escalated at
            # levels >= the faulting level.
            esc_stats = ({k: v for k, v in delta.items() if v > 0}
                         if any(v > 0 for v in delta.values())
                         else {FAMILY_STAT[fam]: 1})
            stage_log.append(f"{stage.label}!overflow")
            tr.end(att, wall_s=dt, outcome="overflow",
                   fatal={k: int(v) for k, v in esc_stats.items()})
            attempts += 1
            if attempts > max_retries + 1:
                fail_stats = {k: int(v) for k, v in fatal.items()}
                close_stage_span(outcome="exhausted")
                raise SolveExhausted(attempts - 1, scales_log, esc_stats,
                                     fail_stats)
            lvl = max(stage.level, 0)
            level_scales = tuner.escalate_levels(level_scales, stage.level,
                                                 esc_stats)
            entry = tuner.format_scales(level_scales[lvl])
            scales_log.append(entry + (f"@L{lvl}" if lvl > 0 else ""))
            tr.instant(f"escalate:{stage.label}", cat="retry",
                       stage=stage.label, scales=entry, level=lvl)
            continue

        # commit the boundary
        if stage_counters:
            counts = introspect.collective_counts(runner, *args)
            stage_collectives.append((stage.label, tuple(sorted(
                counts.items()))))
        stage_log.append(stage.label)
        util = {}
        if plan.telemetry:
            # harvest the stage's per-PE telemetry record before the
            # state is committed/checkpointed (boundary_template does
            # not — and must not — carry it).
            tele_pe = (out[3] if stage.kind == "post"
                       else out_state.pop("_telemetry"))
            agg = tele_lib.aggregate(jax.device_get(tele_pe))
            util = tele_lib.utilization(agg)
            spec_u = _stage_specs(stage, specs)[0]
            tele_records.append(tele_lib.StageRecord(
                label=stage.label, kind=stage.kind, level=stage.level,
                caps={"chase": tuple(spec_u.mail_caps),
                      "sub": (spec_u.cap_sub,),
                      "gather": tuple(
                          max(a, b) for a, b in zip(
                              spec_u.gather_req_cap,
                              spec_u.gather_resp_cap))},
                queue_cap=spec_u.queue_cap, tele=agg))
            tr.counter("telemetry/util_max", util["util_max"])
            tr.counter("telemetry/util_mean", util["util_mean"])
            tr.counter("telemetry/queue_hwm",
                       float(agg.get("queue_hwm", 0)))
        tr.end(att, wall_s=dt, outcome="committed", **util)
        close_stage_span()
        if tr.enabled:
            tr.metrics.histogram(
                "obs/stage_wall_s",
                "device-sync-bounded wall seconds per committed stage"
                ).observe(dt)
            if plan.telemetry:
                tr.metrics.histogram(
                    "telemetry/stage_util_max",
                    tele_lib.TELEMETRY_HELP["util_max"]
                    ).observe(util["util_max"])
        if stage.kind == "post":
            succ_f, rank_f, dev_stats = out[0], out[1], out[2]
            break
        state = out_state
        prev_fatal = fatal
        idx += 1
        if supervisor is not None:
            supervisor.note_stage_time(dt)
            supervisor.boundary(idx, state, make_meta(idx))
        if injector is not None and injector.preempt_after(stage.kind,
                                                           stage.level):
            injected_log.append(f"preempt:{stage.label}")
            tr.instant(f"preempt:{stage.label}", cat="fault",
                       stage=stage.label)
            if supervisor is not None:
                supervisor.preempt()
            else:
                raise Preempted(
                    f"injected preemption after stage {stage.label}")
    else:  # pragma: no cover - schedule always ends with post
        raise AssertionError("schedule ended without a post stage")

    host_stats = {k: int(jax.device_get(v)) for k, v in dev_stats.items()}
    host_stats["attempts"] = attempts
    host_stats["scales_log"] = ";".join(scales_log)
    host_stats["stage_log"] = tuple(stage_log)
    rec = (dict(supervisor.stats) if supervisor is not None else
           {"restarts": crashes, "stragglers": 0, "checkpoints": 0,
            "preempted": 0, "resumed_from": -1})
    rec["injected"] = tuple(injected_log)
    host_stats["recovery"] = rec
    if stage_counters:
        host_stats["stage_collectives"] = tuple(stage_collectives)
    if plan.telemetry:
        host_stats["telemetry"] = {
            "stages": [r.to_json() for r in tele_records],
            "headroom": tele_lib.headroom_rows(tele_records,
                                               scales_log[-1]),
        }
    if supervisor is not None:
        supervisor.ckpt.wait()
    return succ_f, rank_f, host_stats


def _stage_args(stage: Stage, state, succ_d, rank_d, seed):
    if stage.kind == "prep":
        return (succ_d, rank_d)
    if stage.kind == "descend":
        return (state, jnp.int32(seed))
    if stage.kind == "post":
        return (state, succ_d, rank_d)
    return (state,)
