"""Transport backends: one traced per-PE program, two ways to run it.

Every algorithm in this repo is written as a *per-PE body* — per-shard
arrays, collectives by mesh-axis name — and executed by a thin driver.
This module abstracts the driver into a :class:`Transport` plus
:func:`device_run`, with two interchangeable backends:

- **mesh** (:class:`MeshTransport`): the production path. The body runs
  under ``shard_map`` over a real device mesh; collectives are the raw
  ``lax`` primitives, exactly as before this abstraction existed.

- **simshard** (:class:`SimShardTransport`): virtual PEs. The *identical*
  body runs under nested ``vmap`` with the mesh-axis names bound to a
  leading virtual-PE axis on ONE device. JAX's batching rules rewrite
  the named collectives into static data movement at trace time —
  ``all_to_all`` becomes a transpose over the batch axis, ``axis_index``
  an iota, ``psum`` a sum — so any ``p`` (64, 256, 1024, ...) runs in a
  single process with **bit-identical** semantics to the mesh backend
  (verified by the golden pins in ``tests/test_simshard_golden.py``).

Because the vmap rewrite erases the collective eqns from the jaxpr, the
simshard backend wraps each collective in a *named jit marker*
(``simshard_all_to_all`` et al.): the pjit call keeps its name through
batching, and ``introspect.py`` counts markers exactly like real
collectives, keeping the jaxpr-level collective-count pins meaningful on
both backends.

A :class:`SimMesh` is the device-free stand-in for ``jax.Mesh`` (axis
names + sizes only); every front door accepts either. The backend is
chosen per :attr:`ListRankConfig.backend`: ``"auto"`` follows the mesh
object, ``"simshard"`` forces virtual PEs even for a real mesh (same
axis names/sizes, devices ignored), ``"mesh"`` rejects a SimMesh.

Known limits of the simshard backend: the Pallas kernels
(``use_pallas`` / ``use_pallas_pack``) are not supported under the
batched trace and are rejected up front; memory is the real bound on
virtual p — all p shards live on one device
(``benchmarks/simshard_bench.py`` measures how far that pushes).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat

# --------------------------------------------------------------------------
# simulated-collective markers
# --------------------------------------------------------------------------
# Named jit wrappers around the raw collectives. Under vmap the enclosed
# primitive is rewritten into batch-axis data movement at trace time, but
# the pjit eqn keeps the function's name — introspect.count_primitives
# recognizes the ``simshard_`` prefix and counts the marker as the
# collective it stands for (and does not recurse into its body, which
# holds only the lowered transposes/reductions).

SIM_MARKER_PREFIX = "simshard_"


@functools.partial(jax.jit, static_argnums=(1, 2, 3, 4))
def simshard_all_to_all(x, axes, split_axis, concat_axis, tiled):
    return lax.all_to_all(x, axes, split_axis, concat_axis, tiled=tiled)


@functools.partial(jax.jit, static_argnums=(1,))
def simshard_psum(x, axes):
    return lax.psum(x, axes)


@functools.partial(jax.jit, static_argnums=(1, 2))
def simshard_all_gather(x, axes, tiled):
    # jax's vmap batching rule rejects multi-axis all_gather; gathering
    # the minor axis first reproduces the row-major tuple-axis order of
    # the mesh collective exactly. One marker = one mesh collective, so
    # the counts pin identically.
    for a in reversed(axes):
        x = lax.all_gather(x, a, tiled=tiled)
    return x


# --------------------------------------------------------------------------
# transports
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MeshTransport:
    """Raw ``lax`` collectives by axis name (runs under ``shard_map``)."""

    kind = "mesh"

    def axis_index(self, axes: Sequence[str]) -> jax.Array:
        return lax.axis_index(tuple(axes))

    def all_to_all(self, x, axes, split_axis, concat_axis, tiled=True):
        return lax.all_to_all(x, tuple(axes), split_axis, concat_axis,
                              tiled=tiled)

    def psum(self, x, axes):
        return lax.psum(x, tuple(axes))

    def all_gather(self, x, axes, tiled=True):
        return lax.all_gather(x, tuple(axes), tiled=tiled)


@dataclasses.dataclass(frozen=True)
class SimShardTransport:
    """Marker-wrapped collectives (runs under nested ``vmap``)."""

    kind = "simshard"

    def axis_index(self, axes: Sequence[str]) -> jax.Array:
        # vmap's axis_index rule is already an iota; no marker needed
        # (axis_index is not a collective in the §2.6 model).
        return lax.axis_index(tuple(axes))

    def all_to_all(self, x, axes, split_axis, concat_axis, tiled=True):
        return simshard_all_to_all(x, tuple(axes), split_axis, concat_axis,
                                   tiled)

    def psum(self, x, axes):
        return simshard_psum(x, tuple(axes))

    def all_gather(self, x, axes, tiled=True):
        return simshard_all_gather(x, tuple(axes), tiled)


Transport = Any  # MeshTransport | SimShardTransport (duck-typed protocol)


# --------------------------------------------------------------------------
# virtual meshes
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SimMesh:
    """Device-free virtual mesh: axis names and sizes only.

    Drop-in for ``jax.Mesh`` wherever the front doors only read
    ``axis_names`` / ``shape`` — which, by construction, is everywhere
    (placement is the driver's job, and the simshard driver has no
    placement). Hashable, so the jit caches key on it like a real mesh.
    """

    axis_names: tuple[str, ...]
    axis_sizes: tuple[int, ...]

    def __post_init__(self):
        if len(self.axis_names) != len(self.axis_sizes):
            raise ValueError("axis_names and axis_sizes length mismatch")
        if any(s < 1 for s in self.axis_sizes):
            raise ValueError("axis sizes must be positive")

    @property
    def shape(self) -> dict[str, int]:
        return dict(zip(self.axis_names, self.axis_sizes))

    @property
    def size(self) -> int:
        out = 1
        for s in self.axis_sizes:
            out *= s
        return out


def sim_mesh(shape: int | Sequence[int],
             axis_names: Sequence[str] | None = None) -> SimMesh:
    """A virtual mesh of any shape — no devices required.

    ``sim_mesh(256)`` is a flat 256-PE mesh on axis ``"pe"``;
    ``sim_mesh((2, 128), ("row", "col"))`` a 2D grid for indirection.
    """
    if isinstance(shape, int):
        shape = (shape,)
    shape = tuple(int(s) for s in shape)
    if axis_names is None:
        axis_names = ("pe",) if len(shape) == 1 else tuple(
            f"pe{i}" for i in range(len(shape)))
    return SimMesh(axis_names=tuple(axis_names), axis_sizes=shape)


def is_sim(mesh) -> bool:
    return isinstance(mesh, SimMesh)


def backend_name(mesh) -> str:
    """Canonical backend label of a resolved mesh object — used by the
    observability layer (span annotations, trace metadata) and the
    bench harness; keep in sync with ``resolve_backend``."""
    return "simshard" if is_sim(mesh) else "mesh"


def resolve_backend(backend: str, mesh, pe_axes: Sequence[str]):
    """Resolve a ``ListRankConfig.backend`` against the mesh object.

    Returns ``(backend, mesh)`` — with a real mesh swapped for its
    SimMesh twin when simshard is forced.
    """
    pe_axes = tuple(pe_axes)
    if backend == "auto":
        backend = "simshard" if is_sim(mesh) else "mesh"
    if backend == "simshard" and not is_sim(mesh):
        mesh = SimMesh(axis_names=pe_axes,
                       axis_sizes=tuple(mesh.shape[a] for a in pe_axes))
    elif backend == "mesh" and is_sim(mesh):
        raise ValueError("backend='mesh' requires a real device mesh; "
                         "got a SimMesh (use backend='auto'/'simshard')")
    elif backend not in ("mesh", "simshard"):
        raise ValueError(f"unknown transport backend {backend!r}")
    return backend, mesh


def check_sim_config(cfg) -> None:
    """Reject config knobs the batched trace cannot honor."""
    if cfg.use_pallas or cfg.use_pallas_pack:
        raise ValueError(
            "simshard backend does not support the Pallas kernels "
            "(use_pallas/use_pallas_pack); they assume an unbatched "
            "per-PE trace")


def put_sharded(mesh, pe_axes: Sequence[str], x: jax.Array) -> jax.Array:
    """Host->device placement of a block-sharded input: a real
    ``device_put`` on a mesh, a plain array on a SimMesh (the simshard
    runner folds the PE axis itself)."""
    if is_sim(mesh):
        return jnp.asarray(x)
    return jax.device_put(x, NamedSharding(mesh, P(tuple(pe_axes))))


# --------------------------------------------------------------------------
# the one driver: shard_map on a mesh, nested vmap on a SimMesh
# --------------------------------------------------------------------------

def _spec_is_sharded(spec) -> bool:
    if not isinstance(spec, P):
        raise TypeError(f"expected a PartitionSpec, got {spec!r}")
    if len(spec) == 0:
        return False
    if len(spec) == 1 and spec[0] is not None:
        return True
    raise NotImplementedError(
        f"simshard supports P(pe_axes) on axis 0 or P() specs, got {spec}")


def _map_out(out, spec, n_axes: int, flat: int):
    """Apply an out_specs *prefix* to a sim output subtree: sharded
    leaves fold the virtual-PE axes back into axis 0, replicated leaves
    take the (identical) PE-0 copy."""
    if isinstance(spec, P):
        if _spec_is_sharded(spec):
            return jax.tree.map(
                lambda x: x.reshape((-1,) + x.shape[n_axes + 1:]), out)
        return jax.tree.map(lambda x: x[(0,) * n_axes], out)
    if isinstance(spec, dict):
        return {k: _map_out(out[k], spec[k], n_axes, flat) for k in out}
    if isinstance(spec, (list, tuple)):
        return tuple(_map_out(o, s, n_axes, flat)
                     for o, s in zip(out, spec))
    raise TypeError(f"unsupported out_specs node {spec!r}")


def device_run(mesh, pe_axes: Sequence[str], fn, in_specs, out_specs):
    """Jit the per-PE body ``fn`` for ``mesh``: ``jit(shard_map(fn))``
    on a real mesh, a nested-``vmap`` emulation on a :class:`SimMesh`.

    ``in_specs``/``out_specs`` follow the shard_map convention used
    throughout this repo: ``P(pe_axes)`` = block-sharded on axis 0,
    ``P()`` = replicated (out_specs entries may be pytree prefixes).
    """
    pe_axes = tuple(pe_axes)
    if not is_sim(mesh):
        return jax.jit(compat.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                        out_specs=out_specs,
                                        check_vma=False))

    sizes = tuple(mesh.shape[a] for a in pe_axes)
    flat = 1
    for s in sizes:
        flat *= s
    in_axes = tuple(0 if _spec_is_sharded(s) else None for s in in_specs)
    body = fn
    # innermost vmap binds the minor (fastest-varying) axis, matching
    # the row-major PE flattening of ``lax.axis_index(pe_axes)``.
    for name in reversed(pe_axes):
        body = jax.vmap(body, axis_name=name, in_axes=in_axes, out_axes=0)

    def fold_leaf(x):
        x = jnp.asarray(x)
        if x.shape[0] % flat != 0:
            raise ValueError(
                f"sharded input of size {x.shape[0]} not divisible "
                f"by virtual PE count {flat}")
        return x.reshape(sizes + (-1,) + x.shape[1:])

    def runner(*args):
        margs = []
        for spec, x in zip(in_specs, args):
            if _spec_is_sharded(spec):
                # the spec is a pytree *prefix* of the argument (shard_map
                # convention): fold the PE axis of every leaf, so whole
                # state pytrees (stores, stat dicts) ride as one arg.
                margs.append(jax.tree.map(fold_leaf, x))
            else:
                margs.append(x)
        out = body(*margs)
        return _map_out(out, out_specs, len(sizes), flat)

    return jax.jit(runner)
