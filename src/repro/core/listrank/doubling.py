"""Distributed pointer doubling (Wyllie), engineered per the paper:
request aggregation (dedup), message indirection, and overflow-tolerant
rounds. Serves both as the standalone PD baseline of the paper's
evaluation and as the SRS base case.

Each round, every unfinished element asks the owner of its current
successor for (succ[succ[i]], rank[succ[i]]) and applies
  rank[i] += rank[succ[i]];  succ[i] = succ[succ[i]].
Terminals absorb (self-loop, weight 0), so ceil(log2(maxlen)) rounds
suffice. Requests that overflow a mailbox are simply retried next round
— doubling is idempotent w.r.t. skipped updates, trading rounds for
capacity, never correctness.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.listrank import store as store_lib
from repro.core.listrank.exchange import MeshPlan, remote_gather
from repro.obs import telemetry as tele_lib


def doubling_solve(plan: MeshPlan, st: store_lib.Store,
                   owner_of, req_cap: int, resp_cap: int,
                   max_steps: int, dedup: bool = True):
    """Run pointer doubling over a store. Returns (store, stats)."""

    def cond(carry):
        st, pending, steps, stats = carry
        return (pending > 0) & (steps < max_steps)

    def body(carry):
        st, _, steps, stats = carry
        done = (st.succ == st.ids) | ~st.valid
        resp, answered, gst = remote_gather(
            plan, st.succ, st.valid & ~done,
            owner_of,
            lambda g, v: store_lib.lookup(st, g, v),
            req_cap, resp_cap, dedup=dedup)
        upd = answered & resp["found"] & ~done
        new_succ = jnp.where(upd, resp["succ"], st.succ)
        new_rank = jnp.where(upd, st.rank + resp["rank"], st.rank)
        # finished once the successor is a fixed point (terminal)
        now_done = done | (upd & (resp["succ"] == st.succ))
        st2 = st.replace(succ=new_succ, rank=new_rank)
        pending = plan.psum(jnp.sum((~now_done) & st.valid).astype(jnp.int32))
        stats = {
            "pd_rounds": stats["pd_rounds"] + 1,
            "pd_msgs": stats["pd_msgs"] + gst["req_sent"] + gst["resp_sent"],
            "pd_undelivered": stats["pd_undelivered"] + gst["undelivered"],
        }
        if plan.telemetry:
            stats["telemetry"] = tele_lib.merge(carry[3]["telemetry"],
                                                gst["telemetry"])
        return st2, pending, steps + 1, stats

    stats0 = {"pd_rounds": jnp.int32(0), "pd_msgs": jnp.int32(0),
              "pd_undelivered": jnp.int32(0)}
    if plan.telemetry:
        stats0["telemetry"] = tele_lib.route_zero(plan.indirection.depth)
    st, pending, steps, stats = lax.while_loop(
        cond, body, (st, jnp.int32(1), jnp.int32(0), stats0))
    stats["pd_converged"] = (pending == 0)
    return st, stats


def allgather_solve(plan: MeshPlan, st: store_lib.Store, max_len_bound: int = 0):
    """Small-base-case alternative: replicate the sub-instance on every
    PE (one all-gather) and finish with local vectorized Wyllie.

    Engineering option beyond the paper's PD base case; profitable when
    the subproblem is tiny and PD's log(n') latency-bound rounds
    dominate. Cost: one all-gather of the store + O(cap·p·log) local work.
    """
    ids = plan.all_gather(st.ids)
    succ = plan.all_gather(st.succ)
    rank = plan.all_gather(st.rank)
    valid = plan.all_gather(st.valid)
    order = jnp.argsort(jnp.where(valid, ids, jnp.iinfo(jnp.int32).max))
    ids_s, succ_s, rank_s, valid_s = ids[order], succ[order], rank[order], valid[order]
    n = ids_s.shape[0]
    slot = jnp.clip(jnp.searchsorted(ids_s, succ_s), 0, n - 1).astype(jnp.int32)
    found = (ids_s[slot] == succ_s) & valid_s
    slot = jnp.where(found, slot, jnp.arange(n, dtype=jnp.int32))
    # the gathered instance has n slots; lists can be up to n long
    steps = max(1, int(n).bit_length()) + 1

    def body(_, sr):
        s, r = sr
        return s[s], r + r[s]

    slot_f, rank_f = lax.fori_loop(0, steps, body, (slot, rank_s))
    succ_f = ids_s[slot_f]
    # write back into this PE's slots: invert the sort permutation to
    # find where this PE's gathered rows (me*cap + j) landed.
    cap = st.ids.shape[0]
    me = plan.my_id()
    inv = jnp.zeros(n, jnp.int32).at[order].set(jnp.arange(n, dtype=jnp.int32))
    my_slots = inv[me * cap + jnp.arange(cap, dtype=jnp.int32)]
    out = st.replace(succ=jnp.where(st.valid, succ_f[my_slots], st.succ),
                     rank=jnp.where(st.valid, rank_f[my_slots], st.rank))
    stats = {"pd_rounds": jnp.int32(steps), "pd_msgs": jnp.int32(0),
             "pd_undelivered": jnp.int32(0), "pd_converged": jnp.bool_(True)}
    return out, stats
