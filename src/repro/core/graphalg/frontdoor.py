"""graphalg front doors: edges in, components / forests / tree
statistics out — each as ONE jitted mesh program per attempt.

``graph_stats`` chains every stage inside a single ``shard_map``-ed
program (the "edges → rooted forest → Euler tour → stats" pipeline):

  1. hooking + pointer-jumping rounds (:mod:`graphalg.cc`) — component
     labels (= min node id) and spanning-forest edge marks;
  2. unrooted-tour construction (:mod:`graphalg.forest`) — the forest's
     Euler tour cut at each component's min-id root;
  3. a full list-ranking solve (``api._solve_sharded`` — the identical
     in-mesh solver the public ``rank_list`` drives) with unit weights:
     tour positions, hence the *orientation* (parent array) of every
     forest edge and each node's subtree size;
  4. a second solve over the same successor array with the now-known
     ±1 depth weights;
  5. finalization: each tree's start arc broadcasts the tour length L
     to the root's owner, every down-arc scatters its child's
     ``(parent, rank1_down, rank1_up, rank±_down)`` to the child's
     owner, and every node fetches its tree's L through one more
     aggregated gather — closed-form arc arithmetic turns these into
     depth / subtree size / pre- & postorder (DESIGN.md §8 formulas,
     re-derived for the unrooted construction in §9).

``connected_components`` and ``spanning_forest`` run prefixes of the
same body (stages 1 and 1–3). All capacities are host-derived
(:func:`graphalg.cc.derive_caps` + ``api.build_specs`` for the solves);
any overflow surfaces as a fatal stat and the driver retries with the
tuner's targeted escalation — the ``graph`` family for hooking/tour
capacities, the usual chase/sub/gather families for the solver's.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.listrank import api as api_lib
from repro.core.listrank import tuner
from repro.core.listrank.config import ListRankConfig
from repro.core.listrank.exchange import MeshPlan
from repro.core.listrank import exchange as exchange_lib
from repro.core.listrank import transport as transport_lib
from repro.core.listrank.srs import _merge, gather_until_done, zero_stats
from repro.core.graphalg import cc as cc_lib
from repro.core.graphalg import forest as forest_lib
from repro.obs import telemetry as tele_lib
from repro.obs import trace as trace_lib
# the single int32 wire-format id headroom constant (arc ids reach
# 2*E_pad and must stay addressable)
from repro.core.treealg.batch import PACKED_ID_LIMIT as _ID_LIMIT

FATAL_KEYS = api_lib.FATAL_KEYS + cc_lib.GRAPH_FATAL_KEYS


@dataclasses.dataclass(frozen=True)
class GraphStats:
    """Per-node outputs of :func:`graph_stats` (host numpy).

    ``depth``/``subtree_size``/``preorder``/``postorder`` are the tree
    statistics of the spanning forest rooted at each component's
    minimum node id; pre/postorder are 0-based per tree. The
    ``is_ancestor``/interval helpers are the closed-form query layer
    over those numbers (no further solves or collectives).
    """
    components: np.ndarray    #: component label (= min node id)
    parent: np.ndarray        #: oriented spanning forest, root-parented
    depth: np.ndarray
    subtree_size: np.ndarray
    preorder: np.ndarray
    postorder: np.ndarray
    stats: dict

    @property
    def n_nodes(self) -> int:
        return self.components.shape[0]

    @property
    def roots(self) -> np.ndarray:
        return np.flatnonzero(self.components == np.arange(self.n_nodes))

    @property
    def n_components(self) -> int:
        return int(self.roots.shape[0])

    def component_size(self, v) -> np.ndarray:
        """Size of the component containing node(s) ``v``."""
        return self.subtree_size[self.components[v]]

    def same_component(self, u, v) -> np.ndarray:
        return self.components[u] == self.components[v]

    def is_ancestor(self, u, v) -> np.ndarray:
        """True iff ``u`` is an ancestor of ``v`` (inclusive) in the
        spanning forest — closed-form from the pre/postorder numbers
        (``treealg.ops.is_ancestor``)."""
        from repro.core.treealg import ops
        return ops.is_ancestor(self.preorder, self.postorder,
                               self.components, u, v)

    def subtree_interval(self, u):
        """Preorder interval [lo, hi] covered by ``u``'s subtree."""
        from repro.core.treealg import ops
        return ops.subtree_interval(self.preorder, self.subtree_size, u)


# --------------------------------------------------------------------------
# the per-PE pipeline (runs under shard_map)
# --------------------------------------------------------------------------

def _pipeline_sharded(edges, seed, *, plan: MeshPlan, cfg: ListRankConfig,
                      caps: cc_lib.GraphCaps, specs, m: int, m_e: int,
                      mode: str):
    pe = plan.my_id().astype(jnp.int32)
    base = pe * m
    gid = base + jnp.arange(m, dtype=jnp.int32)
    ebase = pe * m_e
    arc_gid = 2 * ebase + jnp.arange(2 * m_e, dtype=jnp.int32)
    ea = edges[:, 0].astype(jnp.int32)
    eb = edges[:, 1].astype(jnp.int32)

    def owner_node(g):
        return g // m

    # graph-pipeline counters plus the solver's (the two in-program
    # solves _merge into the same dict)
    stats = {**zero_stats(), **cc_lib.zero_graph_stats()}
    if plan.telemetry:
        stats["telemetry"] = tele_lib.stage_zero(plan.indirection.depth)

    def finish(out, stats):
        # telemetry stays per-PE (a 3rd sharded output); the remaining
        # stats leaves are all psum'd and ride the replicated out-spec.
        tele = stats.pop("telemetry", None)
        if tele is not None:
            return out, stats, jax.tree.map(lambda v: v[None], tele)
        return out, stats

    # ---- 1. components + spanning-forest edge marks
    f, fmask, stats = cc_lib.cc_rounds(plan, caps, ea, eb, m, m_e, stats)
    if mode == "cc":
        return finish({"components": f}, stats)

    # ---- 2. unrooted Euler tour of the forest
    succ_t, w1, first_mask, tst = forest_lib.build_forest_tour(
        plan, caps, ea, eb, fmask, f, m, m_e)
    stats["tour_msgs"] = stats["tour_msgs"] + plan.psum(tst["sent"])
    stats["tour_undelivered"] = stats["tour_undelivered"] + plan.psum(
        tst["leftover"])
    if plan.telemetry:
        stats = _merge(stats, {"telemetry": {"graph": tst["telemetry"]}})

    # ---- 3. unit-weight ranking -> positions -> orientation
    sout1 = api_lib._solve_sharded(
        succ_t, w1, seed, plan=plan, cfg=cfg, specs=specs, m=2 * m_e)
    rank1, sst1 = sout1[1], sout1[2]
    stats = _merge(stats, sst1)
    if plan.telemetry:
        stats = _merge(stats, {"telemetry": jax.tree.map(
            lambda v: v[0], sout1[3])})
    child, parent_of, r1_down, r1_up, down0 = forest_lib.orient_forest(
        rank1, ea, eb, m_e)

    scaps = [caps.tour] * plan.indirection.depth
    if mode == "forest":
        # deliver each child its parent; roots keep themselves
        dlv, dval, _, pst = exchange_lib.route(
            plan, scaps, {"c": child, "q": parent_of},
            owner_node(child).astype(jnp.int32), fmask)
        cslot = jnp.where(dval, dlv["c"] - base, m)
        parent = gid.at[cslot].set(dlv["q"], mode="drop")
        have = jnp.zeros(m, jnp.bool_).at[cslot].set(True, mode="drop")
        miss = jnp.sum(~have & (f != gid)).astype(jnp.int32)
        stats["stats_undelivered"] = stats["stats_undelivered"] + plan.psum(
            pst["leftover"] + miss)
        if plan.telemetry:
            stats = _merge(stats,
                           {"telemetry": {"graph": pst["telemetry"]}})
        return finish({"components": f, "parent": parent}, stats)

    # ---- 4. ±1 depth weights over the same tour
    w2 = forest_lib.pm_weights(succ_t, arc_gid, fmask, down0)
    sout2 = api_lib._solve_sharded(
        succ_t, w2, seed + 1, plan=plan, cfg=cfg, specs=specs, m=2 * m_e)
    rankpm, sst2 = sout2[1], sout2[2]
    stats = _merge(stats, sst2)
    if plan.telemetry:
        stats = _merge(stats, {"telemetry": jax.tree.map(
            lambda v: v[0], sout2[3])})
    rpm = rankpm.reshape(m_e, 2)
    rpm_down = jnp.where(down0, rpm[:, 0], rpm[:, 1])

    # ---- 5a. tree length L to each root's owner (tour start arcs:
    # L = rank1(start) + 1)
    fm = first_mask.reshape(m_e, 2)
    has_first = fm[:, 0] | fm[:, 1]
    r1m = rank1.reshape(m_e, 2)
    L_val = jnp.where(fm[:, 0], r1m[:, 0], r1m[:, 1]) + 1
    # the start arc is a down-arc out of the root: its parent side
    root_node = parent_of
    ldlv, lval, _, lst = exchange_lib.route(
        plan, [caps.scalar] * plan.indirection.depth,
        {"r": root_node, "L": L_val},
        owner_node(root_node).astype(jnp.int32), has_first)
    rslot = jnp.where(lval, ldlv["r"] - base, m)
    L_arr = jnp.zeros(m, jnp.int32).at[rslot].set(ldlv["L"], mode="drop")

    # ---- 5b. per-child stats to the child's owner
    sdlv, sval, _, sst = exchange_lib.route(
        plan, scaps,
        {"c": child, "q": parent_of, "rd": r1_down, "ru": r1_up,
         "rpm": rpm_down},
        owner_node(child).astype(jnp.int32), fmask)
    cslot = jnp.where(sval, sdlv["c"] - base, m)
    parent = gid.at[cslot].set(sdlv["q"], mode="drop")
    rd = jnp.zeros(m, jnp.int32).at[cslot].set(sdlv["rd"], mode="drop")
    ru = jnp.zeros(m, jnp.int32).at[cslot].set(sdlv["ru"], mode="drop")
    rpmd = jnp.zeros(m, jnp.int32).at[cslot].set(sdlv["rpm"], mode="drop")
    have = jnp.zeros(m, jnp.bool_).at[cslot].set(True, mode="drop")
    miss = jnp.sum(~have & (f != gid)).astype(jnp.int32)

    # ---- 5c. every node fetches its tree's L (aggregated gather)
    def lookup_L(gids, valid):
        slots = jnp.clip(gids - base, 0, m - 1).astype(jnp.int32)
        return {"L": L_arr[slots]}

    lresp, lans, lgst = gather_until_done(
        plan, f, jnp.ones(m, jnp.bool_), owner_node, lookup_L,
        caps.scalar, caps.scalar, dedup=True)
    L_of = jnp.where(lans, lresp["L"], 0)
    stats["stats_undelivered"] = stats["stats_undelivered"] + \
        lgst["undelivered"] + plan.psum(
            lst["leftover"] + sst["leftover"] + miss)
    if plan.telemetry:
        finale = tele_lib.merge(tele_lib.merge(lst["telemetry"],
                                               sst["telemetry"]),
                                lgst["telemetry"])
        stats = _merge(stats, {"telemetry": {"graph": finale}})

    # ---- closed-form per-node statistics (DESIGN.md §9)
    is_nonroot = have
    depth = jnp.where(is_nonroot, 2 - rpmd, 0)
    size = jnp.where(is_nonroot, (rd - ru + 1) // 2, L_of // 2 + 1)
    pos_down = L_of - 1 - rd
    pos_up = L_of - 1 - ru
    pre = jnp.where(is_nonroot, (pos_down + 1 + depth) // 2, 0)
    post = jnp.where(is_nonroot, (pos_up + 2 - depth) // 2 - 1,
                     jnp.maximum(L_of // 2, 0))
    out = {"components": f, "parent": parent, "depth": depth,
           "subtree_size": size, "preorder": pre, "postorder": post}
    return finish(out, stats)


@functools.lru_cache(maxsize=128)
def _jitted_pipeline(mesh, plan, cfg, caps, specs, m, m_e, mode):
    fn = functools.partial(_pipeline_sharded, plan=plan, cfg=cfg, caps=caps,
                           specs=specs, m=m, m_e=m_e, mode=mode)
    spec = P(plan.pe_axes)
    out_specs = (dict.fromkeys(_OUT_KEYS[mode], spec), P())
    if plan.telemetry:
        out_specs = out_specs + (spec,)
    return transport_lib.device_run(
        mesh, plan.pe_axes, fn, in_specs=(spec, P()),
        out_specs=out_specs)


_OUT_KEYS = {
    "cc": ("components",),
    "forest": ("components", "parent"),
    "stats": ("components", "parent", "depth", "subtree_size",
              "preorder", "postorder"),
}


# --------------------------------------------------------------------------
# host drivers
# --------------------------------------------------------------------------

def _check_edges(edges, n_nodes: int) -> np.ndarray:
    edges = np.asarray(jax.device_get(edges))
    if edges.ndim != 2 or edges.shape[1] != 2:
        raise ValueError("edges must be an (E, 2) array of node ids")
    if n_nodes <= 0:
        raise ValueError("n_nodes must be positive")
    edges = edges.astype(np.int64)
    if edges.size and not ((edges >= 0) & (edges < n_nodes)).all():
        raise ValueError("edge endpoints out of range")
    return edges


def _prepare(edges, n_nodes, mesh, pe_axes, cfg):
    """Shared host-side prep: padding, plan, capacity derivation."""
    cfg = cfg or ListRankConfig()
    pe_axes = tuple(pe_axes) if pe_axes is not None \
        else tuple(mesh.axis_names)
    backend, mesh = transport_lib.resolve_backend(cfg.backend, mesh, pe_axes)
    if backend == "simshard":
        transport_lib.check_sim_config(cfg)
    edges = _check_edges(edges, n_nodes)
    plan = MeshPlan.from_mesh(mesh, pe_axes, None,
                              wire_packing=cfg.wire_packing,
                              pallas_pack=cfg.use_pallas_pack,
                              telemetry=cfg.telemetry)
    p = plan.p
    n_pad = n_nodes + (-n_nodes) % p
    m = n_pad // p
    # padding edges are self-loops at node 0: they never propose a hook
    # and never join the forest, so no validity plumbing is needed
    e_pad = max(edges.shape[0], p)
    e_pad = e_pad + (-e_pad) % p
    m_e = e_pad // p
    if n_pad >= _ID_LIMIT or 2 * e_pad >= _ID_LIMIT:
        raise ValueError(
            f"instance too large for int32 ids: n_pad={n_pad}, "
            f"2*E_pad={2 * e_pad} must stay below {_ID_LIMIT}")
    edges_pad = np.zeros((e_pad, 2), np.int64)
    edges_pad[:edges.shape[0]] = edges

    base_caps = cc_lib.derive_caps(edges_pad, n_pad, p, cfg)
    if cfg.algorithm == "auto":
        cfg = cfg.with_(algorithm=tuner.choose_algorithm(
            cfg, p, plan.indirection.depth, 2 * m_e))
    return cfg, mesh, plan, edges_pad, base_caps, n_pad, m, e_pad, m_e


def _attempt_specs(cfg, plan, m_e: int, e_pad: int,
                   scales: tuner.CapacityScales = tuner.CapacityScales()):
    """Solve-stage spec ladder for one attempt — the single derivation
    behind both the driver and the traced footprint. The in-program
    solves rank a tour over *edge-sharded* arcs: a node's incident
    arcs all live on edge PEs, so wave traffic concentrates harder
    than the uniform-list expectation behind the §2 capacity
    derivation — the chase/queue slack starts doubled (measured:
    first-attempt clean at benchmark scale, where the default slack
    needed two escalations). The two solves share one ladder over the
    2*E_pad-arc instance; every arc may be a terminal (self-loop
    padding), hence the full term bound."""
    cfg_solve = cfg.with_(capacity_slack=2 * cfg.capacity_slack,
                          queue_slack=2 * cfg.queue_slack)
    return api_lib.build_specs(cfg_solve, plan, 2 * m_e, 2 * e_pad,
                               term_bound=2 * m_e, scales=scales)


def pipeline_collective_footprint(edges, n_nodes: int, mesh,
                                  pe_axes: Sequence[str] | None = None,
                                  cfg: ListRankConfig | None = None,
                                  mode: str = "stats"):
    """Trace the pipeline's mesh program and return its collective
    ``{prim: (count, payload_bytes)}`` footprint (first-attempt
    capacities). The hooking/shortcut loops are ``while_loop``s, so the
    count is *static* — independent of the edge count and instance —
    which is exactly the coalescing invariant the tests pin. Traces
    the very program the driver runs on attempt 1 (same jit cache)."""
    from repro.core.listrank import introspect
    cfg, mesh, plan, edges_pad, caps, n_pad, m, e_pad, m_e = _prepare(
        edges, n_nodes, mesh, pe_axes, cfg)
    specs = _attempt_specs(cfg, plan, m_e, e_pad)
    runner = _jitted_pipeline(mesh, plan, cfg, caps, specs, m, m_e, mode)
    return introspect.collective_footprint(
        runner, jnp.asarray(edges_pad, jnp.int32), jnp.int32(0))


def _run_pipeline(edges, n_nodes, mesh, pe_axes, cfg, mode, seed,
                  max_retries, tracer=None):
    cfg, mesh, plan, edges_pad, base_caps, n_pad, m, e_pad, m_e = _prepare(
        edges, n_nodes, mesh, pe_axes, cfg)
    edges_d = transport_lib.put_sharded(mesh, plan.pe_axes,
                                        jnp.asarray(edges_pad, jnp.int32))
    tr = trace_lib.ensure(tracer)

    scales = tuner.CapacityScales()
    last_stats = None
    with tr.span(f"graphalg:{mode}", cat="solve", n_nodes=n_nodes,
                 p=plan.p, mode=mode,
                 backend=transport_lib.backend_name(mesh)) as pipe_span:
        for attempt in range(max_retries + 1):
            caps = base_caps.scaled(scales.graph)
            specs = _attempt_specs(cfg, plan, m_e, e_pad, scales)
            runner = _jitted_pipeline(mesh, plan, cfg, caps, specs, m, m_e,
                                      mode)
            att = tr.begin(f"graphalg:{mode}#{attempt + 1}",
                           cat="stage-attempt", stage=f"graphalg:{mode}",
                           level=-1, attempt=attempt + 1,
                           scales=tuner.format_scales(scales))
            if tr.enabled:
                att.annotate(**_pipeline_prediction(
                    runner, edges_pad, plan, cfg, mesh))
            t0 = time.time()
            outs = runner(edges_d, jnp.int32(seed))
            jax.block_until_ready(jax.tree.leaves(outs))
            dt = time.time() - t0
            out, stats = outs[0], outs[1]
            host_stats = {k: int(jax.device_get(v)) for k, v in stats.items()}
            host_stats["attempts"] = attempt + 1
            fatal = sum(host_stats.get(k, 0) for k in FATAL_KEYS)
            if fatal == 0:
                util = {}
                if plan.telemetry:
                    agg = tele_lib.aggregate(jax.device_get(outs[2]))
                    util = tele_lib.utilization(agg)
                    spec0 = specs[0]
                    rec = tele_lib.StageRecord(
                        label=f"graphalg:{mode}", kind="pipeline", level=-1,
                        caps={"chase": tuple(spec0.mail_caps),
                              "sub": (spec0.cap_sub,),
                              "gather": tuple(
                                  max(a, b) for a, b in zip(
                                      spec0.gather_req_cap,
                                      spec0.gather_resp_cap)),
                              "graph": (caps.tour,)},
                        queue_cap=spec0.queue_cap, tele=agg)
                    host_stats["telemetry"] = {
                        "stages": [rec.to_json()],
                        "headroom": tele_lib.headroom_rows(
                            [rec], tuner.format_scales(scales))}
                    tr.counter("telemetry/util_max", util["util_max"])
                    tr.counter("telemetry/util_mean", util["util_mean"])
                tr.end(att, wall_s=dt, outcome="committed", **util)
                host = {k: np.asarray(jax.device_get(v))[:n_nodes]
                        for k, v in out.items()}
                pipe_span.annotate(attempts=attempt + 1, outcome="ok")
                if tr.enabled:
                    from repro.obs import metrics as metrics_lib
                    metrics_lib.ingest_host_stats(tr.metrics, host_stats,
                                                  prefix=f"graphalg/{mode}/")
                return host, host_stats
            tr.end(att, wall_s=dt, outcome="overflow",
                   fatal={k: host_stats[k] for k in FATAL_KEYS
                          if host_stats.get(k, 0) > 0})
            last_stats = host_stats
            scales = tuner.escalate(scales, host_stats)
            tr.instant(f"escalate:graphalg:{mode}", cat="retry",
                       scales=tuner.format_scales(scales))
        pipe_span.annotate(outcome="exhausted")
    raise RuntimeError(
        f"graphalg {mode} did not complete after {max_retries + 1} "
        f"attempts; stats={last_stats}")


def _pipeline_prediction(runner, edges_pad, plan, cfg, mesh):
    """Static §2.6 prediction annotations for one pipeline attempt
    (trace-only; cached per jitted runner — see resume.run_staged)."""
    from repro.core.listrank import introspect
    from repro.obs import cost as cost_lib
    key = id(runner)
    if key not in _FOOTPRINT_CACHE:
        _FOOTPRINT_CACHE[key] = introspect.collective_footprint(
            runner, jnp.asarray(edges_pad, jnp.int32), jnp.int32(0))
    fprint = _FOOTPRINT_CACHE[key]
    sim = transport_lib.is_sim(mesh)
    pred = cost_lib.predict_stage(fprint, plan, cfg.machine, sim)
    count, nbytes = cost_lib.total_collectives(fprint)
    if sim:
        nbytes //= max(plan.p, 1)
    return {"predicted_s": pred["total_s"], "collective_count": count,
            "payload_bytes": nbytes,
            "footprint": cost_lib.footprint_summary(fprint)}


#: per-runner footprint cache (runners are pinned by _jitted_pipeline's
#: lru_cache, so ids are stable while cached).
_FOOTPRINT_CACHE: dict = {}


def connected_components(edges, n_nodes: int, mesh,
                         pe_axes: Sequence[str] | None = None,
                         cfg: ListRankConfig | None = None, seed: int = 0,
                         max_retries: int = 3, tracer=None):
    """Connected components of an undirected edge list on the mesh.

    Returns (labels, stats): ``labels[v]`` is the minimum node id of
    v's component (a canonical labeling).
    """
    out, stats = _run_pipeline(edges, n_nodes, mesh, pe_axes, cfg, "cc",
                               seed, max_retries, tracer=tracer)
    return out["components"], stats


def spanning_forest(edges, n_nodes: int, mesh,
                    pe_axes: Sequence[str] | None = None,
                    cfg: ListRankConfig | None = None, seed: int = 0,
                    max_retries: int = 3, tracer=None):
    """Oriented spanning forest of an undirected edge list.

    Returns (parent, labels, stats): ``parent`` is a rooted forest of
    *graph edges* — each component spanned and rooted at its minimum
    node id (``parent[root] == root``) — which feeds directly into
    ``treealg`` (``tree_stats`` / ``solve_forest`` / ``root_tree``).
    """
    out, stats = _run_pipeline(edges, n_nodes, mesh, pe_axes, cfg,
                               "forest", seed, max_retries, tracer=tracer)
    return out["parent"], out["components"], stats


def graph_stats(edges, n_nodes: int, mesh,
                pe_axes: Sequence[str] | None = None,
                cfg: ListRankConfig | None = None, seed: int = 0,
                max_retries: int = 3, tracer=None) -> GraphStats:
    """Components, oriented spanning forest, and per-node tree
    statistics from a raw edge list — one jitted mesh program.

    Returns a :class:`GraphStats` with, per node: component label,
    spanning-forest parent, depth, subtree size and pre/postorder
    numbers (plus the closed-form ``is_ancestor``/interval query layer
    over them).
    """
    out, stats = _run_pipeline(edges, n_nodes, mesh, pe_axes, cfg, "stats",
                               seed, max_retries, tracer=tracer)
    return GraphStats(components=out["components"], parent=out["parent"],
                      depth=out["depth"], subtree_size=out["subtree_size"],
                      preorder=out["preorder"], postorder=out["postorder"],
                      stats=stats)
