"""Distributed graph algorithms on top of the list-ranking engine.

The paper motivates list ranking as "a subroutine for solving other
problems"; ``treealg`` built the tree-algorithm layer but still assumed
a rooted parent array. This package closes the gap from *raw edge
lists*: distributed connectivity and spanning forests via hooking +
pointer-jumping contraction rounds on the coalesced exchange layer,
then the unrooted-Euler-tour rooting technique (list ranking again) to
orient the forest and read off every tree statistic.

- :mod:`~repro.core.graphalg.cc` — hooking rounds: min-label hooking
  onto component roots, winner-edge recording, pointer jumping,
- :mod:`~repro.core.graphalg.forest` — Euler tours of unrooted forests
  in the edge-sharded arc layout (orientation falls out of the rank),
- :mod:`~repro.core.graphalg.frontdoor` — ``connected_components``,
  ``spanning_forest`` and the end-to-end ``graph_stats`` (edges in,
  per-node depth/subtree/pre/postorder out, ONE jitted mesh program)
  with the closed-form ``is_ancestor``/interval query layer.
"""
from repro.core.graphalg.cc import (GRAPH_FATAL_KEYS, GraphCaps, derive_caps,
                                    endpoint_histogram)
from repro.core.graphalg.frontdoor import (GraphStats, connected_components,
                                           graph_stats,
                                           pipeline_collective_footprint,
                                           spanning_forest)

__all__ = [
    "GRAPH_FATAL_KEYS", "GraphCaps", "derive_caps", "endpoint_histogram",
    "GraphStats", "connected_components", "graph_stats",
    "pipeline_collective_footprint", "spanning_forest",
]
