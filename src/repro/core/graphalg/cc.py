"""Distributed connected components: min-label hooking + pointer
jumping over block-sharded edge lists (the graphalg contraction core).

The input is an edge list sharded like every other instance here — PE k
owns edges ``[k*mE, (k+1)*mE)`` and nodes ``[k*m, (k+1)*m)`` — and the
whole computation is bulk-synchronous rounds where every remote access
rides the packed exchange layer (one ``all_to_all`` per hop). Per
hooking round:

  1. **label gather** — every edge fetches its endpoints' current
     labels ``f[a], f[b]`` (static targets, host-exact capacities from
     the endpoint histogram, request dedup per PE);
  2. **hook proposals** — every cross-label edge proposes
     ``f[max(la,lb)] = min(la,lb)`` to the owner of the larger label;
     the owner applies the min proposal per *root* (``f[t] == t`` — a
     node is hooked at most once, and always onto a strictly smaller
     label, so the hook structure can never cycle) and resolves the
     winning edge by a second scatter-min on edge ids;
  3. **winner confirmation** — each hooked root confirms its winning
     edge back to that edge's owning PE, which marks it as a
     spanning-forest edge (one confirmed edge per hook = exactly
     ``n - #components`` marks, and every mark merged two at-that-time
     distinct components: the marks form a spanning forest);
  4. **shortcut** — pointer jumping ``f = f[f]`` to a fixed point, so
     next round's labels are component roots again.

Labels only decrease and every component's minimum node id never
hooks, so the algorithm converges with ``label == min node id of the
component`` — a canonical labeling that doubles as the root choice for
the spanning forest. Each round hooks every root that is not a local
minimum among its neighbor components, which empirically converges in
O(log n) rounds; the round budget is part of :class:`GraphCaps` and a
``cc_unconverged`` stat triggers the tuner's ``graph``-family retry
(doubled budget), same as every capacity here.

Unlike the list-ranking chase, the proposal/confirmation destinations
follow the *dynamic* label structure (hotspots concentrate on small
labels), so those capacities are slack-based with targeted escalation
rather than host-exact — exactly the second communication pattern the
tuner's capacity families exist for.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.listrank import exchange as exchange_lib
from repro.core.listrank.config import ListRankConfig
from repro.core.listrank.exchange import INT_MAX, MeshPlan
from repro.core.listrank.srs import gather_until_done
from repro.obs import telemetry as tele_lib

#: graphalg's own stat keys; the ``cc_*``/``tour_*``/``stats_*`` fatal
#: keys map to the tuner's ``graph`` capacity family (tuner.FAMILY_OF).
GRAPH_FATAL_KEYS = ("cc_undelivered", "cc_unconverged", "tour_undelivered",
                    "stats_undelivered")


@dataclasses.dataclass(frozen=True)
class GraphCaps:
    """Host-derived static capacities of the graphalg pipeline.

    ``label`` and ``tour`` are sized from the exact endpoint histogram
    of the full edge list (an upper bound for the forest subset, the
    same discipline as ``treealg.euler.tour_caps``); the rest bound
    dynamic-destination traffic with slack and rely on the retry loop.
    """

    label: int     #: endpoint label gather (host-exact, static targets)
    prop: int      #: hook proposals to label owners (dynamic)
    confirm: int   #: winner confirmations to edge owners (dynamic)
    jump: int      #: pointer-jump gathers f[f] (dynamic, deduped)
    tour: int      #: adjacency reports/replies + stats scatter (exact)
    scalar: int    #: per-tree scalar traffic (tour length broadcast)
    rounds: int    #: hooking-round budget
    jumps: int     #: shortcut iterations per hooking round

    def scaled(self, scale: float) -> "GraphCaps":
        """The tuner's ``graph``-family escalation: every capacity —
        including the round budget — times ``scale``."""
        if scale == 1.0:
            return self
        s = max(scale, 1.0)
        return GraphCaps(*(int(math.ceil(getattr(self, f.name) * s))
                           for f in dataclasses.fields(self)))


def endpoint_histogram(edges: np.ndarray, p: int, m: int) -> np.ndarray:
    """Exact (edge-owner PE, endpoint-owner PE) message histogram of
    one endpoint-addressed round — both endpoints of every edge."""
    e_pad = edges.shape[0]
    m_e = e_pad // p
    src = np.repeat(np.arange(e_pad) // m_e, 2)
    dst = edges.reshape(-1) // m
    hist = np.zeros((p, p), np.int64)
    np.add.at(hist, (src, dst), 1)
    return hist


def derive_caps(edges: np.ndarray, n_pad: int, p: int,
                cfg: ListRankConfig) -> GraphCaps:
    """Host-side capacity derivation for the pipeline (attempt 1)."""
    e_pad = edges.shape[0]
    m_e = e_pad // p
    m = n_pad // p
    slack = cfg.capacity_slack
    hist_max = int(endpoint_histogram(edges, p, m).max()) if e_pad else 0
    exact = max(cfg.min_capacity, hist_max)
    per_peer = lambda q: max(cfg.min_capacity,
                             int(math.ceil(slack * q / p)))
    logn = max(int(math.ceil(math.log2(max(n_pad, 2)))), 1)
    return GraphCaps(
        label=exact,
        prop=per_peer(m_e),
        confirm=per_peer(m),
        jump=per_peer(m),
        tour=exact,
        scalar=per_peer(m),
        rounds=2 * logn + 16,
        jumps=logn + 8,
    )


#: schema of the graphalg pipeline's stat counters (repro.obs.metrics
#: ingests them under these help strings; sync with zero_graph_stats).
GRAPH_STAT_HELP = {
    "cc_rounds": "hooking + shortcut rounds executed",
    "cc_msgs": "hooking/shortcut messages routed",
    "cc_undelivered": "FATAL: hooking-pipeline messages undelivered",
    "cc_unconverged": "FATAL: labels not converged within round budget",
    "tour_undelivered": "FATAL: Euler-tour construction undelivered",
    "tour_msgs": "Euler-tour construction messages",
    "stats_undelivered": "FATAL: tree-stats scatter undelivered",
    "forest_edges": "spanning-forest edges selected (gauge)",
}


def zero_graph_stats():
    z = jnp.int32(0)
    return {"cc_rounds": z, "cc_msgs": z, "cc_undelivered": z,
            "cc_unconverged": z, "tour_undelivered": z, "tour_msgs": z,
            "stats_undelivered": z, "forest_edges": z}


def _lookup_labels(f, base, m):
    """Owner-side label lookup for gather rounds (targets are global
    node ids; routing guarantees they are owned here)."""
    def lookup(gids, valid):
        slots = jnp.clip(gids - base, 0, m - 1).astype(jnp.int32)
        return {"lab": f[slots]}
    return lookup


def _shortcut(plan: MeshPlan, caps: GraphCaps, f, base, m, owner_of):
    """Pointer jumping ``f = f[f]`` to a fixed point (bounded).

    Returns ``(f, undelivered, msgs, tele)``; ``tele`` is the merged
    per-PE routing telemetry (None unless ``plan.telemetry``)."""
    def cond(c):
        f, changed, it, und, msgs, _ = c
        return (changed > 0) & (it < caps.jumps)

    def body(c):
        f, _, it, und, msgs, tele = c
        resp, answered, gst = gather_until_done(
            plan, f, jnp.ones(m, jnp.bool_), owner_of,
            _lookup_labels(f, base, m), caps.jump, caps.jump, dedup=True)
        nf = jnp.where(answered, resp["lab"], f)
        changed = plan.psum(jnp.sum(nf != f).astype(jnp.int32))
        if plan.telemetry:
            tele = tele_lib.merge(tele, gst["telemetry"])
        return nf, changed, it + 1, und + gst["undelivered"], \
            msgs + gst["msgs"], tele

    tele0 = (tele_lib.route_zero(plan.indirection.depth)
             if plan.telemetry else None)
    f, _, _, und, msgs, tele = lax.while_loop(
        cond, body, (f, jnp.int32(1), jnp.int32(0), jnp.int32(0),
                     jnp.int32(0), tele0))
    return f, und, msgs, tele


def cc_rounds(plan: MeshPlan, caps: GraphCaps, ea, eb, m: int, m_e: int,
              stats):
    """The hooking loop (runs under shard_map).

    Args:
      ea/eb: (m_e,) int32 per-PE edge endpoints (global node ids);
        padding edges are self-loops and never propose.

    Returns (f, fmask, stats): the converged labels (m,), the local
    spanning-forest edge marks (m_e,), and updated stats.
    """
    pe = plan.my_id().astype(jnp.int32)
    base = pe * m
    gid = base + jnp.arange(m, dtype=jnp.int32)
    ebase = pe * m_e
    eid = ebase + jnp.arange(m_e, dtype=jnp.int32)

    def owner_node(g):
        return g // m

    f0 = gid
    fmask0 = jnp.zeros(m_e, jnp.bool_)
    targets = jnp.concatenate([ea, eb]).astype(jnp.int32)
    tvalid = jnp.ones(2 * m_e, jnp.bool_)

    def cond(c):
        f, fmask, changed, it, st = c
        return (changed > 0) & (it < caps.rounds)

    def body(c):
        f, fmask, _, it, st = c
        # 1. endpoint labels (static targets, host-exact caps)
        resp, answered, gst = gather_until_done(
            plan, targets, tvalid, owner_node, _lookup_labels(f, base, m),
            caps.label, caps.label, dedup=True)
        la, lb = resp["lab"][:m_e], resp["lab"][m_e:]
        # gather stats come back already psum'd; route stats are local
        gund = gst["undelivered"]
        msgs = gst["msgs"]
        und = jnp.int32(0)

        # 2. hook proposals: cross-label edges to the larger label
        both = answered[:m_e] & answered[m_e:]
        pvalid = both & (la != lb)
        tgt = jnp.maximum(la, lb)
        val = jnp.minimum(la, lb)
        pcaps = [caps.prop] * plan.indirection.depth
        dlv, dval, _, pst = exchange_lib.route(
            plan, pcaps, {"t": tgt, "v": val, "e": eid},
            owner_node(tgt).astype(jnp.int32), pvalid)
        und = und + pst["leftover"]
        msgs = msgs + sum(pst["sent"]).astype(jnp.int32)

        # 3. apply: min proposal per root, winner edge by second
        # scatter-min among the entries achieving it
        slot = jnp.where(dval, dlv["t"] - base, m)
        slot_c = jnp.clip(slot, 0, m - 1)
        ok = dval & (f[slot_c] == dlv["t"])  # target still a root
        minval = jnp.full(m + 1, INT_MAX, jnp.int32).at[
            jnp.where(ok, slot, m)].min(dlv["v"], mode="drop")[:m]
        hooked = minval < INT_MAX
        win = ok & (dlv["v"] == minval[slot_c])
        weid = jnp.full(m + 1, INT_MAX, jnp.int32).at[
            jnp.where(win, slot, m)].min(dlv["e"], mode="drop")[:m]
        f = jnp.where(hooked, minval, f)
        n_hooked = plan.psum(jnp.sum(hooked).astype(jnp.int32))

        # 4. confirm winning edges to their owning PEs
        ccaps = [caps.confirm] * plan.indirection.depth
        weid_c = jnp.where(hooked, weid, 0)
        cdlv, cval, _, cst = exchange_lib.route(
            plan, ccaps, {"e": weid_c},
            (weid_c // m_e).astype(jnp.int32), hooked)
        und = und + cst["leftover"]
        msgs = msgs + sum(cst["sent"]).astype(jnp.int32)
        eslot = jnp.where(cval, cdlv["e"] - ebase, m_e)
        fmask = fmask.at[eslot].set(True, mode="drop")

        # 5. shortcut to stars for the next round
        f, jund, jmsgs, jtele = _shortcut(plan, caps, f, base, m, owner_node)
        st = dict(st)
        st["cc_rounds"] = st["cc_rounds"] + 1
        st["cc_msgs"] = st["cc_msgs"] + plan.psum(msgs + jmsgs)
        st["cc_undelivered"] = st["cc_undelivered"] + gund + jund + \
            plan.psum(und)
        if plan.telemetry:
            # all four hooking legs ride graph-family caps; per-PE only.
            round_tele = tele_lib.merge(
                tele_lib.merge(gst["telemetry"], jtele),
                tele_lib.merge(pst["telemetry"], cst["telemetry"]))
            st["telemetry"] = tele_lib.merge(st["telemetry"],
                                             {"graph": round_tele})
        return f, fmask, n_hooked, it + 1, st

    init = (f0, fmask0, jnp.int32(1), jnp.int32(0), stats)
    f, fmask, changed, it, stats = lax.while_loop(cond, body, init)
    # a nonzero `changed` at exit means the round budget ran out with
    # hooks still firing — unconverged, retry with a doubled budget
    stats = dict(stats)
    stats["cc_unconverged"] = stats["cc_unconverged"] + changed
    stats["forest_edges"] = plan.psum(jnp.sum(fmask).astype(jnp.int32))
    return f, fmask, stats
