"""Euler tours of *unrooted* spanning forests (edge-list layout).

``treealg.euler`` builds tours from a parent array — the orientation is
an input. Here the forest arrives as the undirected edge marks that the
hooking rounds produced (:func:`graphalg.cc.cc_rounds`), so the tour
must be built from raw adjacency and the orientation *falls out of the
ranking* (JáJá's tree-rooting technique): rank the tour cut at each
component's root, and for every forest edge the arc traversed first is
the parent→child direction.

Arc layout: forest edge at global edge slot ``e`` owns the arc pair
``2e`` (a→b) and ``2e+1`` (b→a) — arcs shard with the edges, twins are
co-located, and ``owner(arc) = arc // (2 m_E)``. Construction is one
:func:`exchange.request_reply` round, exactly the euler.py two-round
discipline:

  1. every forest edge reports ``(node, in_arc, out_arc)`` to each
     endpoint's owner;
  2. the owner groups the reports per node (pre-sort by *neighbor* id,
     then the shared ``sort_and_group`` — giving each node the
     ascending-neighbor circular adjacency order, i.e. treealg's
     ascending-child sibling convention), links each in-arc to
     the *next* out-arc around the node (wrapping), cuts the wrap at
     component roots (``label == id`` — the min-id node) to make the
     tour's terminal, flags the root's first out-arc as the tree's
     start, and replies to the arc owners (in-arc and out-arc are
     twins, one reply serves both).

The tour successor array plus unit weights is a list-ranking instance
over ``2 m_E`` arcs per PE; non-forest edges' arcs are weight-0
self-loops (padding), so the instance shards perfectly regardless of
how many edges won hooks. Capacities for both legs come from the exact
endpoint histogram of the *full* edge list — a host-side upper bound
for the forest subset, same discipline as ``treealg.euler.tour_caps``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.listrank import exchange as exchange_lib
from repro.core.listrank.exchange import INT_MAX, MeshPlan
from repro.core.graphalg.cc import GraphCaps


def build_forest_tour(plan: MeshPlan, caps: GraphCaps, ea, eb, fmask,
                      f, m: int, m_e: int):
    """Device-side tour construction (runs under shard_map).

    Args:
      ea/eb: (m_e,) per-PE edge endpoints (global node ids).
      fmask: (m_e,) spanning-forest marks from the hooking rounds.
      f: (m,) converged component labels (roots are ``f[v] == v``).

    Returns (succ, w_unit, first_mask, stats_local): the (2*m_e,) tour
    successor and unit weights, the tree-start arc marks, and *local*
    (un-psummed) {"sent", "leftover"} transport counters.
    """
    pe = plan.my_id().astype(jnp.int32)
    base = pe * m
    gid = base + jnp.arange(m, dtype=jnp.int32)
    ebase = pe * m_e
    eid = ebase + jnp.arange(m_e, dtype=jnp.int32)
    is_root = f == gid
    arc_gid = 2 * ebase + jnp.arange(2 * m_e, dtype=jnp.int32)

    def owner_node(g):
        return g // m

    def owner_arc(a):
        return a // (2 * m_e)

    # one report per (forest edge, endpoint): the in-arc entering the
    # endpoint, the out-arc leaving it, and the neighbor at the far end
    node = jnp.concatenate([ea, eb]).astype(jnp.int32)
    nbr = jnp.concatenate([eb, ea]).astype(jnp.int32)
    ain = jnp.concatenate([2 * eid + 1, 2 * eid])
    aout = jnp.concatenate([2 * eid, 2 * eid + 1])
    rvalid = jnp.concatenate([fmask, fmask])

    def reply_fn(dlv, dval):
        nd, ai, ao = dlv["node"], dlv["ain"], dlv["aout"]
        # canonical circular adjacency: ascending *neighbor id* per node
        # (pre-sort by neighbor, then stable group by node — euler.py's
        # single-sort discipline), so pre/postorder visit children in
        # ascending-id order, the treealg convention. The forest never
        # keeps parallel edges (a merged pair stops proposing), so the
        # neighbor key is unique within a node's run.
        orda = jnp.argsort(jnp.where(dval, dlv["nbr"], INT_MAX),
                           stable=True)
        nd_c, ai_c, ao_c, val_c = nd[orda], ai[orda], ao[orda], dval[orda]
        order, skey, pos, newrun = exchange_lib.sort_and_group(
            nd_c, val_c, INT_MAX)
        ai_s, ao_s = ai_c[order], ao_c[order]
        val_s = skey != INT_MAX
        q = val_s.shape[0]
        i = jnp.arange(q, dtype=jnp.int32)

        # circular next: in-arc i links to the next entry's out-arc,
        # wrapping the last entry of each run to the run's first
        last = jnp.concatenate([newrun[1:], jnp.ones((1,), jnp.bool_)])
        first_out = ao_s[i - pos]  # run start = i - pos
        nxt = jnp.where(last, first_out,
                        jnp.concatenate([ao_s[1:], ao_s[:1]]))
        # cut at component roots: the wrap arc terminates the tour, and
        # the root's first out-arc is the tree's start
        nslot = jnp.clip(skey - base, 0, m - 1)
        rooted = val_s & is_root[nslot]
        cut = last & rooted
        succ_val = jnp.where(cut, ai_s, nxt)
        fflag = newrun & rooted
        return ({"ain": ai_s, "succ": succ_val, "aout": ao_s,
                 "fflag": fflag}, owner_arc(ai_s), val_s)

    rdel, rval, _, rr_st = exchange_lib.request_reply(
        plan, caps.tour, caps.tour,
        {"node": node, "nbr": nbr, "ain": ain, "aout": aout},
        owner_node(node).astype(jnp.int32), rvalid, reply_fn)

    # receive: in-arc successors and tree-start flags (twin arcs are
    # co-located, so one delivery serves both)
    aslot = jnp.where(rval, rdel["ain"] - 2 * ebase, 2 * m_e)
    succ = arc_gid.at[aslot].set(rdel["succ"], mode="drop")
    oslot = jnp.where(rval & rdel["fflag"], rdel["aout"] - 2 * ebase,
                      2 * m_e)
    first_mask = jnp.zeros(2 * m_e, jnp.bool_).at[oslot].set(
        True, mode="drop")
    have = jnp.zeros(2 * m_e, jnp.bool_).at[aslot].set(True, mode="drop")

    # every forest arc must have received its successor
    expect = jnp.repeat(fmask, 2)
    missing = jnp.sum(expect & ~have).astype(jnp.int32)
    w_unit = (succ != arc_gid).astype(jnp.int32)
    stats_local = {"sent": rr_st["sent"],
                   "leftover": rr_st["leftover"] + missing}
    if plan.telemetry:
        stats_local["telemetry"] = rr_st["telemetry"]
    return succ, w_unit, first_mask, stats_local


def orient_forest(rank1, ea, eb, m_e: int):
    """Per-edge orientation from the unit ranking: the arc with the
    larger rank-to-terminal comes earlier in the tour and is the
    parent→child traversal.

    Returns (child, parent, r1_down, r1_up, down0) per local edge
    slot, computed for *every* slot — callers gate on their forest
    mask downstream; ``down0`` marks edges whose even arc (a→b) is
    the downward one.
    """
    r = rank1.reshape(m_e, 2)
    r0, r1 = r[:, 0], r[:, 1]
    down0 = r0 > r1
    child = jnp.where(down0, eb, ea).astype(jnp.int32)
    parent = jnp.where(down0, ea, eb).astype(jnp.int32)
    r1_down = jnp.where(down0, r0, r1)
    r1_up = jnp.where(down0, r1, r0)
    return child, parent, r1_down, r1_up, down0


def pm_weights(succ, arc_gid, fmask, down0):
    """±1 depth weights for the second solve: +1 on down-arcs, -1 on
    up-arcs, 0 on terminals and non-forest self-loops."""
    w_even = jnp.where(down0, jnp.int32(1), jnp.int32(-1))
    w = jnp.stack([w_even, -w_even], axis=1).reshape(arc_gid.shape[0])
    live = jnp.repeat(fmask, 2) & (succ != arc_gid)
    return jnp.where(live, w, 0)
