"""Parameter schema: shape + dtype + logical sharding axes + init.

Models declare a pytree of :class:`ParamSpec`; from it we derive
  - ``init_params``: materialized arrays (smoke tests / real training),
  - ``abstract_params``: ShapeDtypeStructs (the dry-run path — no
    allocation ever happens for the full-size configs),
  - sharding via ``repro.runtime.sharding.tree_shardings``.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    dtype: jnp.dtype = jnp.float32
    init: str = "normal"        # normal | zeros | ones | small_normal
    scale: float | None = None  # overrides the fan-in scale

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def spec(shape: Sequence[int], axes: Sequence[str | None], dtype=jnp.float32,
         init: str = "normal", scale: float | None = None) -> ParamSpec:
    return ParamSpec(tuple(shape), tuple(axes), jnp.dtype(dtype), init, scale)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def abstract_params(spec_tree):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        spec_tree, is_leaf=is_spec)


def init_params(key, spec_tree):
    flat, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(flat))
    out = []
    for k, s in zip(keys, flat):
        if s.init == "zeros":
            out.append(jnp.zeros(s.shape, s.dtype))
        elif s.init == "ones":
            out.append(jnp.ones(s.shape, s.dtype))
        else:
            fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
            scale = s.scale if s.scale is not None else fan_in ** -0.5
            if s.init == "small_normal":
                scale = s.scale if s.scale is not None else 0.02
            out.append((jax.random.normal(k, s.shape, jnp.float32)
                        * scale).astype(s.dtype))
    return jax.tree.unflatten(treedef, out)


def count_params(spec_tree) -> int:
    flat = jax.tree.leaves(spec_tree, is_leaf=is_spec)
    total = 0
    for s in flat:
        n = 1
        for d in s.shape:
            n *= d
        total += n
    return total
