"""Model building blocks, pure-JAX with logical-axis annotated params.

Every block has a ``*_specs(cfg)`` (ParamSpec tree) and an apply
function. Attention and the SSD scan dispatch to the Pallas kernels
when ``cfg.use_kernels`` (smoke tests / real TPU); the dry-run path
lowers the pure-jnp references so the 512-device SPMD partitioner sees
plain XLA ops.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.models.params import spec

# ---------------------------------------------------------------------------
# norms / rope / embedding
# ---------------------------------------------------------------------------


def rms_norm_spec(d):
    return {"scale": spec((d,), ("embed",), init="ones")}


def rms_norm(p, x, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * p["scale"].astype(x.dtype)


def rope(x, positions, theta):
    """x: (..., L, H, D) rotary over last dim; positions: (..., L)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., L, half)
    ang = ang[..., None, :]  # broadcast over heads
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def embed_specs(cfg):
    return {"embedding": spec((cfg.padded_vocab, cfg.d_model),
                              ("vocab", "embed"), cfg.dtype, "small_normal")}


def embed(p, tokens, cfg):
    x = jnp.take(p["embedding"], tokens, axis=0)
    return x * jnp.asarray(cfg.d_model ** 0.5, x.dtype) if cfg.scale_embeddings else x


def unembed(p, x, cfg):
    logits = jnp.einsum("...d,vd->...v", x, p["embedding"]).astype(jnp.float32)
    # pin the logits layout (batch over dp axes, vocab over tp): without
    # this, sharding propagation may replicate the tied embedding at the
    # unembed site and compute full-vocab logits per device (§Perf
    # P-dense: a 9x per-device FLOP regression under pure-DP mappings).
    from repro.runtime import context as _rc
    ctx = _rc.current()
    if ctx is not None:
        from jax.sharding import PartitionSpec as P
        mesh = ctx.mesh
        bdim = logits.shape[0]
        dp = tuple(a for a in ctx.dp_axes if a in mesh.axis_names)
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        spec = [None] * logits.ndim
        if dp and bdim % dp_size == 0:
            spec[0] = dp if len(dp) > 1 else dp[0]
        if ctx.tp_axis and logits.shape[-1] % mesh.shape[ctx.tp_axis] == 0:
            spec[-1] = ctx.tp_axis
        logits = jax.lax.with_sharding_constraint(
            logits, jax.NamedSharding(mesh, P(*spec)))
    if cfg.final_softcap:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: jax.Array  # (B, Hkv, S, Dh)
    v: jax.Array


def attention_specs(cfg, cross: bool = False):
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    s = {
        "wq": spec((d, hq * dh), ("embed", "qkv_features"), cfg.dtype),
        "wk": spec((d, hkv * dh), ("embed", "kv_features"), cfg.dtype),
        "wv": spec((d, hkv * dh), ("embed", "kv_features"), cfg.dtype),
        "wo": spec((hq * dh, d), ("qkv_features", "embed"), cfg.dtype),
    }
    if cfg.qkv_bias and not cross:
        s["bq"] = spec((hq * dh,), ("qkv_features",), cfg.dtype, "zeros")
        s["bk"] = spec((hkv * dh,), ("kv_features",), cfg.dtype, "zeros")
        s["bv"] = spec((hkv * dh,), ("kv_features",), cfg.dtype, "zeros")
    return s


def _project_qkv(p, xq, xkv, cfg):
    b, lq, _ = xq.shape
    lk = xkv.shape[1]
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = xq @ p["wq"]
    k = xkv @ p["wk"]
    v = xkv @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, lq, hq, dh)
    k = k.reshape(b, lk, hkv, dh)
    v = v.reshape(b, lk, hkv, dh)
    return q, k, v


def _sdpa(q, k, v, cfg, *, causal, window, q_offset):
    """q: (B,L,H,D) -> (B,L,H,D); dispatches kernel vs reference."""
    qh = jnp.swapaxes(q, 1, 2)
    kh = jnp.swapaxes(k, 1, 2)
    vh = jnp.swapaxes(v, 1, 2)
    scale = cfg.attn_scale if cfg.attn_scale else cfg.resolved_head_dim ** -0.5
    if cfg.use_kernels:
        from repro.kernels.flash_attention import ops as fa
        out = fa.flash_attention(qh, kh, vh, causal, window,
                                 cfg.attn_softcap, scale, q_offset, True)
    else:
        from repro.kernels.flash_attention import ref as fa_ref
        out = fa_ref.attention_ref(qh, kh, vh, causal=causal, window=window,
                                   softcap=cfg.attn_softcap, scale=scale,
                                   q_offset=q_offset)
    return jnp.swapaxes(out, 1, 2)


def attention(p, x, cfg, *, positions, causal=True, is_local=None,
              cache: KVCache | None = None, cache_pos=None,
              kv_x=None, kv_positions=None):
    """Self/cross attention with optional KV cache.

    is_local: traced bool scalar — sliding-window layers inside a layer
    scan (lax.cond between windowed and global paths).
    kv_x: encoder output for cross-attention (no cache update path).
    """
    b, lq, _ = x.shape
    xkv = kv_x if kv_x is not None else x
    q, k, v = _project_qkv(p, x, xkv, cfg)
    if kv_x is None:  # rope only for self-attention
        q = rope(q, positions, cfg.rope_theta)
        kv_pos = kv_positions if kv_positions is not None else positions
        k = rope(k, kv_pos, cfg.rope_theta)

    q_offset = 0
    if cache is not None:
        kh = jnp.swapaxes(k, 1, 2)
        vh = jnp.swapaxes(v, 1, 2)
        cp = jnp.asarray(cache_pos)
        if cp.ndim == 1:
            # per-slot positions (continuous-batching decode, lq == 1)
            bidx = jnp.arange(b, dtype=jnp.int32)
            k = cache.k.at[bidx, :, cp].set(kh[:, :, 0])
            v = cache.v.at[bidx, :, cp].set(vh[:, :, 0])
        else:
            # uniform position: contiguous append
            k = jax.lax.dynamic_update_slice(cache.k, kh, (0, 0, cache_pos, 0))
            v = jax.lax.dynamic_update_slice(cache.v, vh, (0, 0, cache_pos, 0))
        new_cache = KVCache(k, v)
        k = jnp.swapaxes(k, 1, 2)
        v = jnp.swapaxes(v, 1, 2)
        q_offset = cache_pos
    else:
        new_cache = None

    qo = q_offset
    if cfg.use_kernels and not isinstance(qo, int):
        # the Pallas kernel takes a static offset; traced/per-slot
        # offsets use the reference path
        cfg = cfg.with_(use_kernels=False)

    def run(window):
        return _sdpa(q, k, v, cfg, causal=causal, window=window,
                     q_offset=qo)

    if is_local is None or cfg.local_window is None:
        out = run(cfg.local_window if cfg.layer_pattern == "local_only" else None)
    else:
        out = jax.lax.cond(is_local, lambda: run(cfg.local_window),
                           lambda: run(None))
    out = out.reshape(b, lq, -1) @ p["wo"]
    return out, new_cache


# ---------------------------------------------------------------------------
# feed-forward: dense SwiGLU and MoE
# ---------------------------------------------------------------------------


def swiglu_specs(cfg, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "w_gate": spec((d, f), ("embed", "mlp"), cfg.dtype),
        "w_up": spec((d, f), ("embed", "mlp"), cfg.dtype),
        "w_down": spec((f, d), ("mlp", "embed"), cfg.dtype),
    }


def swiglu(p, x):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


def moe_specs(cfg):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    s = {
        "router": spec((d, e), ("embed", "experts"), jnp.float32,
                       "small_normal"),
        "w_gate": spec((e, d, f), ("experts", "embed", "expert_mlp"), cfg.dtype),
        "w_up": spec((e, d, f), ("experts", "embed", "expert_mlp"), cfg.dtype),
        "w_down": spec((e, f, d), ("experts", "expert_mlp", "embed"), cfg.dtype),
    }
    if cfg.num_shared_experts:
        s["shared"] = swiglu_specs(cfg, d_ff=cfg.d_ff * cfg.num_shared_experts)
    return s


def moe_ffn(p, x, cfg):
    """MoE FFN dispatcher: expert-parallel shard_map path when a mesh
    context is active (production), single-program sort-based dispatch
    otherwise (single-device tests; also the GSPMD-auto baseline that
    EXPERIMENTS.md §Perf measures against).
    """
    from repro.runtime import context as runtime_context
    ctx = runtime_context.current()
    if ctx is not None and cfg.num_experts % ctx.mesh.shape[ctx.ep_axis] == 0:
        y, aux = moe_ffn_ep(p, x, cfg, ctx)
        # name the output so remat policies can save/offload it instead
        # of re-running the dispatch all_to_alls in the backward pass
        from jax.ad_checkpoint import checkpoint_name
        y = checkpoint_name(y, "moe_out")
        return y, aux
    return _moe_ffn_dense(p, x, cfg)


def _moe_ffn_dense(p, x, cfg):
    """Sort-based top-k dispatch with per-expert capacity (dropless-lite).

    Tokens are flattened, their top-k expert assignments sorted by
    expert id, and packed into an (E, C, D) buffer (overflow dropped —
    capacity_factor controls the drop rate). Expert GEMMs run as one
    batched einsum; results scatter back weighted by the (re-normalized)
    router gates. Aux load-balancing loss is returned for training.
    """
    b, l, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    n = b * l
    xf = x.reshape(n, d)
    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eidx = jax.lax.top_k(probs, k)          # (n, k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux loss (Switch-style load balancing)
    me = probs.mean(axis=0)
    ce = jnp.zeros(e, jnp.float32).at[eidx.reshape(-1)].add(1.0) / (n * k)
    aux_loss = e * jnp.sum(me * ce)

    flat_e = eidx.reshape(-1)                           # (n*k,)
    flat_tok = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e)
    se, stok, sgate = flat_e[order], flat_tok[order], flat_gate[order]
    # position of each assignment within its expert
    starts = jnp.searchsorted(se, jnp.arange(e + 1, dtype=se.dtype))
    pos = jnp.arange(n * k, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    cap = max(8, int(cfg.capacity_factor * n * k / e)) if e > 1 else n * k
    keep = pos < cap
    row = jnp.where(keep, se, e).astype(jnp.int32)
    col = jnp.where(keep, pos, cap).astype(jnp.int32)

    buf = jnp.zeros((e + 1, cap + 1, d), x.dtype).at[row, col].set(
        xf[stok], mode="drop")[:e, :cap]
    h = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    yb = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, p["w_down"])
    # combine back
    gathered = yb[jnp.minimum(row, e - 1), jnp.minimum(col, cap - 1)]
    contrib = jnp.where(keep[:, None], gathered * sgate[:, None].astype(x.dtype), 0)
    y = jnp.zeros((n, d), x.dtype).at[stok].add(contrib)
    if cfg.num_shared_experts:
        y = y + swiglu(p["shared"], xf)
    return y.reshape(b, l, d), aux_loss


# ---------------------------------------------------------------------------
# Mamba-2 mixer
# ---------------------------------------------------------------------------


class SSMCache(NamedTuple):
    conv: jax.Array   # (B, K-1, conv_dim)
    state: jax.Array  # (B, H, N, P)


def _mamba_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    conv_dim = d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return d_inner, n_heads, conv_dim


def mamba_specs(cfg):
    d = cfg.d_model
    d_inner, h, conv_dim = _mamba_dims(cfg)
    g, n = cfg.ssm_groups, cfg.ssm_state
    proj_out = 2 * d_inner + 2 * g * n + h
    return {
        "in_proj": spec((d, proj_out), ("embed", "mlp"), cfg.dtype),
        "conv_w": spec((cfg.ssm_conv, conv_dim), ("conv", "mlp"), cfg.dtype),
        "conv_b": spec((conv_dim,), ("mlp",), cfg.dtype, "zeros"),
        "a_log": spec((h,), ("ssm_heads",), jnp.float32, "zeros"),
        "dt_bias": spec((h,), ("ssm_heads",), jnp.float32, "zeros"),
        "d_skip": spec((h,), ("ssm_heads",), jnp.float32, "ones"),
        "norm": rms_norm_spec(d_inner),
        "out_proj": spec((d_inner, d), ("mlp", "embed"), cfg.dtype),
    }


def _causal_conv(x, w, b, cache=None):
    """x: (B, L, C) depthwise causal conv, kernel (K, C)."""
    k = w.shape[0]
    if cache is not None:
        x_pad = jnp.concatenate([cache, x], axis=1)
        new_cache = x_pad[:, -(k - 1):] if k > 1 else cache
    else:
        x_pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
        new_cache = None
    out = sum(x_pad[:, i:i + x.shape[1]] * w[i] for i in range(k)) + b
    return out, new_cache


def mamba_mixer(p, x, cfg, *, cache: SSMCache | None = None):
    """Mamba-2 block body. x: (B, L, D) -> (B, L, D)."""
    b, l, d = x.shape
    d_inner, h, conv_dim = _mamba_dims(cfg)
    g, n, pdim = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_head_dim
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt_raw = jnp.split(zxbcdt, [d_inner, d_inner + conv_dim], axis=-1)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                 cache.conv if cache is not None else None)
    xbc = jax.nn.silu(xbc)
    xin, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + g * n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    xh = xin.reshape(b, l, h, pdim)
    bh = bmat.reshape(b, l, g, n)
    ch = cmat.reshape(b, l, g, n)

    if cache is not None and l == 1:
        # single-token decode against the carried state
        from repro.kernels.ssd_scan import ops as ssd_ops
        y, new_state = ssd_ops.ssd_decode_step(
            xh[:, 0], dt[:, 0], a, bh[:, 0], ch[:, 0], p["d_skip"],
            cache.state)
        y = y[:, None]
        new_cache = SSMCache(new_conv, new_state)
    elif cache is not None:
        # prefill: chunked scan, build the cache for subsequent decoding
        from repro.kernels.ssd_scan import ref as ssd_ref
        y, new_state = ssd_ref.ssd_chunked_ref(
            xh, dt, a, bh, ch, p["d_skip"], chunk=cfg.ssm_chunk,
            return_state=True)
        # conv cache holds the last K-1 *pre-conv* channel inputs
        xbc_tail = zxbcdt[:, -(cfg.ssm_conv - 1):,
                          d_inner:d_inner + conv_dim]
        new_cache = SSMCache(xbc_tail.astype(cache.conv.dtype), new_state)
    else:
        if cfg.use_kernels:
            from repro.kernels.ssd_scan import ops as ssd_ops
            y = ssd_ops.ssd_scan(xh, dt, a, bh, ch, p["d_skip"],
                                 cfg.ssm_chunk, True)
        else:
            from repro.kernels.ssd_scan import ref as ssd_ref
            y = ssd_ref.ssd_chunked_ref(xh, dt, a, bh, ch, p["d_skip"],
                                        chunk=cfg.ssm_chunk)
        new_cache = None
    y = y.reshape(b, l, d_inner)
    y = rms_norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ p["out_proj"], new_cache


def init_ssm_cache(cfg, batch, dtype):
    d_inner, h, conv_dim = _mamba_dims(cfg)
    return SSMCache(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        state=jnp.zeros((batch, h, cfg.ssm_state, cfg.ssm_head_dim),
                        jnp.float32))


# ---------------------------------------------------------------------------
# Hymba mixer: parallel attention + SSM heads (arXiv:2411.13676)
# ---------------------------------------------------------------------------


def hymba_specs(cfg):
    return {
        "attn": attention_specs(cfg),
        "mamba": mamba_specs(cfg),
        "norm_attn": rms_norm_spec(cfg.d_model),
        "norm_ssm": rms_norm_spec(cfg.d_model),
    }


def hymba_mixer(p, x, cfg, *, positions, is_local=None, cache=None,
                cache_pos=None):
    """Parallel attn+SSM heads, outputs mean-fused after per-branch
    normalization (the paper's beta-weighted mean, with beta = 1)."""
    kv, ssm = (cache if cache is not None else (None, None))
    attn_out, new_kv = attention(p["attn"], x, cfg, positions=positions,
                                 causal=True, is_local=is_local,
                                 cache=kv, cache_pos=cache_pos)
    # hymba's mamba branch keeps the block residual outside; out_proj of
    # the mamba sub-block maps back to d_model so the mean is welldefined
    ssm_out, new_ssm = mamba_mixer(p["mamba"], x, cfg, cache=ssm)
    out = 0.5 * (rms_norm(p["norm_attn"], attn_out, cfg.norm_eps)
                 + rms_norm(p["norm_ssm"], ssm_out, cfg.norm_eps))
    new_cache = (new_kv, new_ssm) if cache is not None else None
    return out, new_cache


# ---------------------------------------------------------------------------
# expert-parallel MoE (shard_map + the paper's routing engine)
# ---------------------------------------------------------------------------
#
# The auto-partitioned sort/scatter dispatch above is opaque to GSPMD
# (data-dependent scatters cannot be sharded), which the kimi-k2 dry-run
# baseline shows as ~10^14 bytes of all-reduce per step. The production
# path instead runs dispatch *manually* inside shard_map:
#
#   tokens --route(all_to_all over the data axis)--> expert shards
#   (E_loc, C, D) batched GEMMs (d_ff sharded over "model", psum)
#   results --route back--> source shards, gate-weighted combine.
#
# Token routing reuses repro.core.listrank.exchange.route — the paper's
# message-coalescing engine; on multi-pod meshes experts are placed
# within a pod (DP across pods), the topology-aware placement of §2.4.
# Capacity overflow = token drop, the standard MoE semantics; counted.


def moe_ffn_ep(p, x, cfg, ctx):
    """Expert-parallel MoE. x: (B, L, D) sharded over ctx.dp_axes."""
    import functools
    from jax.sharding import PartitionSpec as P
    from repro.core.listrank.config import IndirectionSpec
    from repro.core.listrank.exchange import MeshPlan, route

    mesh = ctx.mesh
    ep = ctx.ep_axis
    tp = ctx.tp_axis
    e_total = cfg.num_experts
    p_ep = mesh.shape[ep]
    assert e_total % p_ep == 0, (e_total, p_ep)
    e_loc = e_total // p_ep
    dp_spec = P(ctx.dp_axes, None, None)
    w_spec = P(ep, None, tp)      # (E, D, F)
    w_spec_t = P(ep, tp, None)    # (E, F, D)
    shared_specs = {k: P(None, tp) if k != "w_down" else P(tp, None)
                    for k in ("w_gate", "w_up", "w_down")}

    def body(xb, router, wg, wu, wd, shared):
        b_loc, l, d = xb.shape
        s = b_loc * l
        k = cfg.top_k
        xf = xb.reshape(s, d)
        logits = xf.astype(jnp.float32) @ router.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, eidx = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True),
                                         1e-9)
        # aux loss over the local shard (pmean'd below)
        me = probs.mean(axis=0)
        ce = jnp.zeros(e_total, jnp.float32).at[eidx.reshape(-1)].add(
            1.0) / (s * k)
        aux = e_total * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, ctx.dp_axes)

        q = s * k
        flat_e = eidx.reshape(-1).astype(jnp.int32)
        flat_gate = gate_vals.reshape(-1).astype(xb.dtype)
        flat_x = jnp.repeat(xf, k, axis=0)
        slot = jnp.arange(q, dtype=jnp.int32)

        plan = MeshPlan.from_mesh(mesh, (ep,), IndirectionSpec.direct((ep,)))
        me_id = plan.my_id().astype(jnp.int32)
        dest = flat_e // e_loc
        # per-dest-shard mailbox: shard-level loads pool e_loc experts,
        # so a binomial mean+5sigma bound suffices (1.03x padding at
        # kimi scale vs the 1.25x naive slack — §Perf P2); per-expert
        # capacity below keeps the capacity_factor drop semantics.
        m_dest = q / p_ep
        cap_send = min(q, int(m_dest + 5.0 * m_dest ** 0.5) + 8)
        payload = {"x": flat_x, "g": flat_gate, "slot": slot,
                   "src": jnp.full((q,), 0, jnp.int32) + me_id,
                   "e": flat_e}
        delivered, dval, leftovers, _ = route(
            plan, [cap_send], payload, dest, jnp.ones(q, bool))
        dropped_route = sum(jnp.sum(lv) for *_x, lv in leftovers)

        # group by local expert with per-expert capacity
        r = delivered["e"].shape[0]
        le = jnp.where(dval, delivered["e"] - me_id * e_loc, e_loc)
        order = jnp.argsort(jnp.where(dval, le, e_loc), stable=True)
        sle = jnp.where(dval, le, e_loc)[order]
        starts = jnp.searchsorted(sle, jnp.arange(e_loc + 1, dtype=sle.dtype))
        pos = jnp.arange(r, dtype=jnp.int32) - starts[
            jnp.minimum(sle, e_loc)].astype(jnp.int32)
        cap_e = max(8, int(cfg.capacity_factor * q / e_loc))
        fits = (sle < e_loc) & (pos < cap_e)
        row = jnp.where(fits, sle, e_loc).astype(jnp.int32)
        col = jnp.where(fits, pos, cap_e).astype(jnp.int32)
        xbuf = jnp.zeros((e_loc + 1, cap_e + 1, d), xb.dtype).at[
            row, col].set(delivered["x"][order], mode="drop")[:e_loc, :cap_e]

        h = jnp.einsum("ecd,edf->ecf", xbuf, wg)
        u = jnp.einsum("ecd,edf->ecf", xbuf, wu)
        yb = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, wd)
        # d_ff is sharded over `tp`, so yb holds partial sums. The psum
        # happens AFTER the gate-weighted combine back at the source
        # shard: all-reducing (tokens, d) instead of the padded
        # (E_loc, C, d) buffer cuts all-reduce bytes ~10x (§Perf P1).

        ydel = jnp.zeros((r, d), xb.dtype)
        gathered = yb[jnp.minimum(row, e_loc - 1),
                      jnp.minimum(col, cap_e - 1)]
        gathered = jnp.where(fits[:, None], gathered, 0)
        ydel = ydel.at[order].set(gathered)

        # route results back to the source shard
        back_payload = {"y": ydel, "slot": delivered["slot"],
                        "g": delivered["g"]}
        bdel, bval, bleft, _ = route(plan, [cap_send], back_payload,
                                     delivered["src"], dval)
        sidx = jnp.where(bval, bdel["slot"], q).astype(jnp.int32)
        contrib = jnp.where(bval[:, None],
                            bdel["y"] * bdel["g"][:, None], 0)
        y = jnp.zeros((q + 1, d), xb.dtype).at[sidx].add(
            contrib, mode="drop")[:q]
        y = y.reshape(s, k, d).sum(axis=1)
        if cfg.num_shared_experts:
            hs = jax.nn.silu(xf @ shared["w_gate"]) * (xf @ shared["w_up"])
            y = y + hs @ shared["w_down"]  # also partial over tp
        if tp is not None:
            y = jax.lax.psum(y, tp)  # one combined all-reduce (P1)
        return y.reshape(b_loc, l, d), aux

    in_specs = (dp_spec, P(None, None), w_spec, w_spec, w_spec_t,
                shared_specs if cfg.num_shared_experts else P())
    shared_p = p.get("shared", jnp.zeros((), x.dtype))
    out = compat.shard_map(
        body, mesh=mesh,
        in_specs=in_specs,
        out_specs=(dp_spec, P()),
        check_vma=False)(
        x, p["router"].astype(jnp.float32), p["w_gate"], p["w_up"],
        p["w_down"], shared_p)
    return out
