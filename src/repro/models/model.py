"""The model zoo: one configurable LM covering all assigned families.

Families:
  decoder — dense/GQA decoder (gemma2, qwen2.5, tinyllama, phi4,
            pixtral backbone) with optional MoE FFN (granite, kimi-k2)
            and optional local/global alternating attention (gemma2).
  hybrid  — parallel attention+SSM heads per layer (hymba).
  mamba   — attention-free Mamba-2 stack (mamba2-130m).
  encdec  — encoder-decoder with cross-attention (seamless-m4t
            backbone; audio frontend stubbed as frame embeddings).

Layers are scanned (lax.scan over stacked params) with optional remat —
this keeps the HLO size O(1) in depth, which the 512-device dry-run
relies on. Per-layer binary attributes (local vs global attention) ride
along as scanned boolean arrays and select behaviour via lax.cond.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.params import abstract_params, init_params, spec

VOCAB_PAD = 512


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "tiny"
    family: str = "decoder"          # decoder | hybrid | mamba | encdec
    num_layers: int = 2
    num_encoder_layers: int = 0
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int | None = None
    d_ff: int = 1024
    vocab_size: int = 1024
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    attn_softcap: float | None = None
    final_softcap: float | None = None
    attn_scale: float | None = None
    local_window: int | None = None
    layer_pattern: str = "global"    # global | local_global | sparse_global
    post_norms: bool = False         # gemma2-style post-block norms
    scale_embeddings: bool = False   # gemma2 multiplies embeds by sqrt(d)
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    # MoE
    moe: bool = False
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    # SSM
    ssm_state: int = 128
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 128
    # modality stub (vlm patches / audio frames)
    prefix_embed_dim: int | None = None
    # numerics / runtime
    dtype: Any = jnp.bfloat16
    remat: bool = True
    #: nothing | save_moe | offload_moe — what remat keeps of the MoE
    #: block output (§Perf P3: avoids recomputing dispatch all_to_alls)
    remat_policy: str = "nothing"
    use_kernels: bool = False
    scan_layers: bool = True

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return -(-self.vocab_size // VOCAB_PAD) * VOCAB_PAD

    @property
    def is_local_flags(self) -> tuple[bool, ...]:
        """Per-(decoder-)layer sliding-window flag."""
        n = self.num_layers
        if self.layer_pattern == "local_global":
            return tuple(i % 2 == 0 for i in range(n))
        if self.layer_pattern == "sparse_global":
            # hymba: global attention on first / middle / last layer
            glob = {0, n // 2, n - 1}
            return tuple(i not in glob for i in range(n))
        if self.layer_pattern == "local_only":
            return tuple(True for _ in range(n))
        return tuple(False for _ in range(n))

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# parameter schema
# ---------------------------------------------------------------------------


def _block_specs(cfg: ModelConfig, cross: bool = False):
    s: dict[str, Any] = {"norm_mixer": L.rms_norm_spec(cfg.d_model),
                         "norm_ffn": L.rms_norm_spec(cfg.d_model)}
    if cfg.family == "mamba":
        s["mixer"] = L.mamba_specs(cfg)
        del s["norm_ffn"]
        return s
    if cfg.family == "hybrid":
        s["mixer"] = L.hymba_specs(cfg)
    else:
        s["mixer"] = L.attention_specs(cfg)
    if cross:
        s["cross"] = L.attention_specs(cfg, cross=True)
        s["norm_cross"] = L.rms_norm_spec(cfg.d_model)
    s["ffn"] = L.moe_specs(cfg) if cfg.moe else L.swiglu_specs(cfg)
    if cfg.post_norms:
        s["post_norm_mixer"] = L.rms_norm_spec(cfg.d_model)
        s["post_norm_ffn"] = L.rms_norm_spec(cfg.d_model)
    return s


def _stack_specs(block, n):
    return jax.tree.map(
        lambda sp: spec((n,) + sp.shape, ("layers",) + sp.axes, sp.dtype,
                        sp.init, sp.scale),
        block, is_leaf=lambda x: hasattr(x, "axes"))


def param_specs(cfg: ModelConfig):
    specs: dict[str, Any] = {
        "embed": L.embed_specs(cfg),
        "final_norm": L.rms_norm_spec(cfg.d_model),
        "layers": _stack_specs(_block_specs(cfg, cross=cfg.family == "encdec"),
                               cfg.num_layers),
    }
    if cfg.family == "encdec":
        specs["enc_layers"] = _stack_specs(_block_specs(cfg),
                                           cfg.num_encoder_layers)
        specs["enc_final_norm"] = L.rms_norm_spec(cfg.d_model)
    if cfg.prefix_embed_dim:
        specs["prefix_proj"] = spec((cfg.prefix_embed_dim, cfg.d_model),
                                    ("embed", "embed"), cfg.dtype)
    if not cfg.tie_embeddings:
        specs["lm_head"] = spec((cfg.padded_vocab, cfg.d_model),
                                ("vocab", "embed"), cfg.dtype, "small_normal")
    return specs


def init(key, cfg: ModelConfig):
    return init_params(key, param_specs(cfg))


def abstract(cfg: ModelConfig):
    return abstract_params(param_specs(cfg))


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _block_apply(bp, x, cfg, *, positions, causal, is_local, cache, cache_pos,
                 enc_out):
    """One transformer block. Returns (x, new_cache, aux_loss)."""
    aux = jnp.float32(0.0)
    h = L.rms_norm(bp["norm_mixer"], x, cfg.norm_eps)
    if cfg.family == "mamba":
        out, new_cache = L.mamba_mixer(bp["mixer"], h, cfg, cache=cache)
        return x + out, new_cache, aux
    if cfg.family == "hybrid":
        out, new_cache = L.hymba_mixer(bp["mixer"], h, cfg,
                                       positions=positions,
                                       is_local=is_local, cache=cache,
                                       cache_pos=cache_pos)
    else:
        out, new_cache = L.attention(bp["mixer"], h, cfg, positions=positions,
                                     causal=causal, is_local=is_local,
                                     cache=cache, cache_pos=cache_pos)
    if cfg.post_norms:
        out = L.rms_norm(bp["post_norm_mixer"], out, cfg.norm_eps)
    x = x + out
    if enc_out is not None and "cross" in bp:
        h = L.rms_norm(bp["norm_cross"], x, cfg.norm_eps)
        out, _ = L.attention(bp["cross"], h, cfg, positions=positions,
                             causal=False, kv_x=enc_out)
        x = x + out
    h = L.rms_norm(bp["norm_ffn"], x, cfg.norm_eps)
    if cfg.moe:
        out, aux = L.moe_ffn(bp["ffn"], h, cfg)
    else:
        out = L.swiglu(bp["ffn"], h)
    if cfg.post_norms:
        out = L.rms_norm(bp["post_norm_ffn"], out, cfg.norm_eps)
    return x + out, new_cache, aux


def _run_stack(stacked, x, cfg, *, positions, causal, local_flags, caches,
               cache_pos, enc_out):
    """lax.scan over stacked layer params (remat-able)."""

    def body(carry, inputs):
        x, aux = carry
        bp, is_local, cache = inputs
        x, new_cache, aux_l = _block_apply(
            bp, x, cfg, positions=positions, causal=causal,
            is_local=is_local, cache=cache, cache_pos=cache_pos,
            enc_out=enc_out)
        return (x, aux + aux_l), new_cache

    if cfg.remat:
        if cfg.remat_policy == "save_moe":
            pol = jax.checkpoint_policies.save_only_these_names("moe_out")
        elif cfg.remat_policy == "offload_moe":
            pol = jax.checkpoint_policies.save_and_offload_only_these_names(
                names_which_can_be_saved=[],
                names_which_can_be_offloaded=["moe_out"],
                offload_src="device", offload_dst="pinned_host")
        else:
            pol = jax.checkpoint_policies.nothing_saveable
        body = jax.checkpoint(body, policy=pol)

    flags = jnp.asarray(local_flags, jnp.bool_)
    if cfg.scan_layers:
        (x, aux), new_caches = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                            (stacked, flags, caches))
    else:
        aux = jnp.float32(0.0)
        new_caches = []
        n = flags.shape[0]
        for i in range(n):
            bp = jax.tree.map(lambda a: a[i], stacked)
            cache = jax.tree.map(lambda a: a[i], caches) if caches is not None else None
            (x, aux), nc = body((x, aux), (bp, flags[i], cache))
            new_caches.append(nc)
        if new_caches and new_caches[0] is not None:
            new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)
        else:
            new_caches = None
    return x, aux, new_caches


def _inputs_to_embeds(params, batch, cfg):
    """tokens (+ optional modality prefix embeddings) -> (x, positions)."""
    x = L.embed(params["embed"], batch["tokens"], cfg)
    if cfg.prefix_embed_dim and "prefix_embeds" in batch:
        pre = batch["prefix_embeds"].astype(cfg.dtype) @ params["prefix_proj"]
        x = jnp.concatenate([pre, x], axis=1)
    b, l, _ = x.shape
    positions = jnp.arange(l, dtype=jnp.int32)[None, :].repeat(b, 0)
    return x, positions


def encode(params, batch, cfg: ModelConfig):
    """Encoder stack (encdec family). batch['enc_embeds']: (B, Ls, D_in)
    — the stubbed modality frontend output (precomputed frames)."""
    enc_in = batch["enc_embeds"].astype(cfg.dtype)
    if cfg.prefix_embed_dim:
        enc_in = enc_in @ params["prefix_proj"]
    b, ls, _ = enc_in.shape
    positions = jnp.arange(ls, dtype=jnp.int32)[None, :].repeat(b, 0)
    x, _, _ = _run_stack(params["enc_layers"], enc_in, cfg,
                         positions=positions, causal=False,
                         local_flags=(False,) * cfg.num_encoder_layers,
                         caches=None, cache_pos=None, enc_out=None)
    return L.rms_norm(params["enc_final_norm"], x, cfg.norm_eps)


def forward(params, batch, cfg: ModelConfig):
    """Full-sequence forward -> (logits, aux_loss). Training path."""
    enc_out = encode(params, batch, cfg) if cfg.family == "encdec" else None
    x, positions = _inputs_to_embeds(params, batch, cfg)
    x, aux, _ = _run_stack(params["layers"], x, cfg, positions=positions,
                           causal=True, local_flags=cfg.is_local_flags,
                           caches=None, cache_pos=None, enc_out=enc_out)
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    head = params.get("lm_head", params["embed"]["embedding"])
    logits = L.unembed({"embedding": head}, x, cfg)
    return logits, aux


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    """Stacked per-layer cache pytree (scanned axis leading)."""
    n, hkv, dh = cfg.num_layers, cfg.n_kv_heads, cfg.resolved_head_dim

    def kv():
        return L.KVCache(
            k=jnp.zeros((n, batch, hkv, max_seq, dh), cfg.dtype),
            v=jnp.zeros((n, batch, hkv, max_seq, dh), cfg.dtype))

    def ssm():
        d_inner, h, conv_dim = L._mamba_dims(cfg)
        return L.SSMCache(
            conv=jnp.zeros((n, batch, cfg.ssm_conv - 1, conv_dim), cfg.dtype),
            state=jnp.zeros((n, batch, h, cfg.ssm_state, cfg.ssm_head_dim),
                            jnp.float32))

    if cfg.family == "mamba":
        return ssm()
    if cfg.family == "hybrid":
        return (kv(), ssm())
    return kv()


def cache_axes(cfg: ModelConfig):
    """Logical axes of the cache pytree (mirrors init_cache)."""
    kv_ax = L.KVCache(k=("layers", "batch", "kv_heads", "kv_seq", "head_dim"),
                      v=("layers", "batch", "kv_heads", "kv_seq", "head_dim"))
    ssm_ax = L.SSMCache(conv=("layers", "batch", "conv", "mlp"),
                        state=("layers", "batch", "ssm_heads", "ssm_state",
                               None))
    if cfg.family == "mamba":
        return ssm_ax
    if cfg.family == "hybrid":
        return (kv_ax, ssm_ax)
    return kv_ax


def prefill(params, batch, cfg: ModelConfig, cache):
    """Process the prompt, filling the cache. Returns (last_logits,
    cache). For mamba/hybrid the SSM state is advanced by scanning —
    decode-shaped dry-runs exercise decode_step instead."""
    enc_out = encode(params, batch, cfg) if cfg.family == "encdec" else None
    x, positions = _inputs_to_embeds(params, batch, cfg)
    x, _, new_caches = _run_stack(
        params["layers"], x, cfg, positions=positions, causal=True,
        local_flags=cfg.is_local_flags, caches=cache, cache_pos=0,
        enc_out=enc_out)
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    head = params.get("lm_head", params["embed"]["embedding"])
    logits = L.unembed({"embedding": head}, x[:, -1:], cfg)
    return logits, new_caches


def decode_step(params, tokens, pos, cfg: ModelConfig, cache, enc_out=None):
    """One decode step. tokens: (B, 1); pos: scalar position. Returns
    (logits (B,1,V), new_cache)."""
    x = L.embed(params["embed"], tokens, cfg)
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    x, _, new_caches = _run_stack(
        params["layers"], x, cfg, positions=positions, causal=True,
        local_flags=cfg.is_local_flags, caches=cache, cache_pos=pos,
        enc_out=enc_out)
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    head = params.get("lm_head", params["embed"]["embedding"])
    logits = L.unembed({"embedding": head}, x, cfg)
    return logits, new_caches
