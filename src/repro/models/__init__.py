from repro.models import layers, model, params

__all__ = ["layers", "model", "params"]
