"""LR schedules (cosine with linear warmup, constant, rsqrt)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_warmup(step, *, warmup_steps: int, total_steps: int,
                  min_ratio: float = 0.1):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(warmup_steps, 1)
    prog = jnp.clip((step - warmup_steps)
                    / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup_steps, warm, cos)


def rsqrt(step, *, warmup_steps: int):
    step = jnp.maximum(step.astype(jnp.float32), 1.0)
    return jnp.minimum(step / warmup_steps, jnp.sqrt(warmup_steps / step))


def constant(step, **_):
    return jnp.ones_like(step, jnp.float32)
