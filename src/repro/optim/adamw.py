"""AdamW with distributed-memory options.

- ZeRO-1-style state sharding: optimizer moments (and the fp32 master
  copy when params are bf16) are annotated with an *extra* data-axis
  sharding on their first shardable unsharded dim, fully distributing
  optimizer memory across the mesh (under SPMD this is exactly ZeRO-1:
  states live sharded, updates happen shard-local, params remain in
  their compute sharding).
- int8 moment quantization (blockwise, bitsandbytes-style) as a config
  option — cuts optimizer memory 4x for the trillion-parameter config.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.runtime import compression


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    #: moments dtype: float32 | bfloat16 | int8 (blockwise quantized)
    state_dtype: str = "float32"
    #: keep an fp32 master copy when params are low-precision
    master_weights: bool = True


def _zeros_moment(p, cfg: AdamWConfig):
    if cfg.state_dtype == "int8":
        return compression.QInt8.zeros(p.shape)
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    return jnp.zeros(p.shape, dt)


def init(params, cfg: AdamWConfig):
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: _zeros_moment(p, cfg), params),
        "v": jax.tree.map(lambda p: _zeros_moment(p, cfg), params),
    }
    if cfg.master_weights:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32)
            if p.dtype != jnp.float32 else p, params)
    return state


def _load(x):
    return x.dequantize() if isinstance(x, compression.QInt8) else x.astype(jnp.float32)


def _store(x, like):
    if isinstance(like, compression.QInt8):
        return compression.QInt8.quantize(x)
    return x.astype(like.dtype)


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def update(grads, state, params, cfg: AdamWConfig, lr_scale=1.0):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else 1.0
    lr = cfg.lr * lr_scale

    def upd(g, m, v, p, master):
        g = g.astype(jnp.float32) * clip
        mf = _load(m) * cfg.b1 + (1 - cfg.b1) * g
        vf = _load(v) * cfg.b2 + (1 - cfg.b2) * g * g
        mhat = mf / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = vf / (1 - cfg.b2 ** step.astype(jnp.float32))
        base = master if master is not None else p.astype(jnp.float32)
        new = base - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                           + cfg.weight_decay * base)
        return new.astype(p.dtype), _store(mf, m), _store(vf, v), \
            (new if master is not None else None)

    masters = state.get("master")
    if masters is None:
        masters = jax.tree.map(lambda _: None, params)
    is_q = lambda x: isinstance(x, compression.QInt8)
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"], is_leaf=is_q)
    flat_v = jax.tree.leaves(state["v"], is_leaf=is_q)
    flat_ma = tdef.flatten_up_to(masters) if state.get("master") is not None \
        else [None] * len(flat_p)

    outs = [upd(g, m, v, p, ma) for g, m, v, p, ma in
            zip(flat_g, flat_m, flat_v, flat_p, flat_ma)]
    new_params = tdef.unflatten([o[0] for o in outs])
    new_state = {
        "step": step,
        "m": tdef.unflatten([o[1] for o in outs]),
        "v": tdef.unflatten([o[2] for o in outs]),
    }
    if state.get("master") is not None:
        new_state["master"] = tdef.unflatten([o[3] for o in outs])
    metrics = {"grad_norm": gnorm, "lr": jnp.float32(lr)}
    return new_params, new_state, metrics


# ---------------------------------------------------------------------------
# state sharding (ZeRO-1 under SPMD)
# ---------------------------------------------------------------------------


def state_shardings(param_specs_tree, mesh, cfg: AdamWConfig, rules=None,
                    zero1: bool = True):
    """Shardings for the optimizer state tree.

    Moments/master copies reuse the parameter's resolved spec; with
    ``zero1`` the first replicated, divisible dim is additionally
    sharded over the data (and pod) axes.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.runtime import sharding as shlib

    def one(spec_leaf):
        pspec = shlib.resolve_spec(spec_leaf.shape, spec_leaf.axes, mesh,
                                   rules)
        parts = list(pspec) + [None] * (len(spec_leaf.shape) - len(pspec))
        if zero1:
            used = set()
            for pt in parts:
                if pt is None:
                    continue
                used |= set(pt) if isinstance(pt, tuple) else {pt}
            for axes_try in (("pod", "data"), ("data",)):
                cand = tuple(a for a in axes_try if a in mesh.axis_names
                             and a not in used)
                if not cand:
                    continue
                size = 1
                for a in cand:
                    size *= mesh.shape[a]
                for i, pt in enumerate(parts):
                    if pt is None and spec_leaf.shape[i] % size == 0 \
                            and spec_leaf.shape[i] >= size:
                        parts[i] = cand if len(cand) > 1 else cand[0]
                        break
                else:
                    continue
                break
        return NamedSharding(mesh, P(*parts))

    is_leaf = lambda x: hasattr(x, "axes")
    moment = jax.tree.map(one, param_specs_tree, is_leaf=is_leaf)
    out = {"step": NamedSharding(mesh, P()), "m": moment, "v": moment}
    if cfg.master_weights:
        out["master"] = moment
    return out
