"""Version-adaptive JAX API shims.

The repo targets the modern public API (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``); this container ships an older
jaxlib where those live under ``jax.experimental.shard_map`` /
lack the ``axis_types`` parameter. Everything version-sensitive goes
through here so the rest of the codebase is written once.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax


def shard_map(fn, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` when available, else the experimental one.

    ``check_vma=False`` maps to ``check_rep=False`` on old versions —
    both disable the replication/varying-manual-axes check that the
    per-PE collectives here do not satisfy mechanically.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)


def make_mesh(shape: Sequence[int], axis_names: Sequence[str]) -> Any:
    """``jax.make_mesh`` with Auto axis types when supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(tuple(shape), tuple(axis_names),
                             axis_types=(axis_type.Auto,) * len(axis_names))
    return jax.make_mesh(tuple(shape), tuple(axis_names))


def tree_flatten_with_path(tree, is_leaf=None):
    """``jax.tree.flatten_with_path`` across its move out of tree_util."""
    fn = getattr(jax.tree, "flatten_with_path", None)
    if fn is not None:
        return fn(tree, is_leaf=is_leaf)
    return jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)


def tpu_compiler_params(**kwargs):
    """Pallas TPU compiler params across the TPUCompilerParams rename."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)


def abstract_mesh(shape: Sequence[int], axis_names: Sequence[str]) -> Any:
    """Device-free ``AbstractMesh`` across the signature change (old
    versions take a tuple of (name, size) pairs)."""
    try:
        return jax.sharding.AbstractMesh(tuple(shape), tuple(axis_names))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(axis_names, shape)))
