"""Euler-tour tree computations on top of distributed list ranking —
the paper's motivating application family (§1) and its tree-rooting
future-work direction.

  PYTHONPATH=src python examples/euler_tour.py

Generates a random tree, builds its Euler tour (one list element per
arc), ranks the tour with SRS, and derives from the ranks alone:
  - each node's depth,
  - each node's subtree size,
  - a rooting of the tree (parent pointers) w.r.t. node 0.
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro import compat
from repro.core.listrank import (ListRankConfig, instances,
                                 rank_list_with_stats)


def main():
    p = len(jax.devices())
    mesh = compat.make_mesh((p,), ("pe",))
    n_nodes = 4097
    succ, rank, arcs = instances.gen_euler_tour(n_nodes, seed=3,
                                                locality=True)
    succ, rank = instances.pad_to_multiple(succ, rank, p)
    n_arcs = arcs.shape[0]
    print(f"tree with {n_nodes} nodes -> Euler tour of {n_arcs} arcs")

    cfg = ListRankConfig(srs_rounds=2, local_contraction=True)
    _, rank_out, stats = rank_list_with_stats(succ, rank, mesh, cfg=cfg)
    # rank = #arcs after this arc in the tour; position from the front:
    pos = (n_arcs - 1) - np.asarray(rank_out)[:n_arcs]

    # arc ids: down(c) = 2(c-1), up(c) = 2(c-1)+1 (instances.py layout)
    down_pos = np.full(n_nodes, -1)
    up_pos = np.full(n_nodes, -1)
    for c in range(1, n_nodes):
        down_pos[c] = pos[2 * (c - 1)]
        up_pos[c] = pos[2 * (c - 1) + 1]

    # subtree size: arcs strictly between down(c) and up(c) are the
    # subtree's internal arcs: (up - down - 1) arcs = 2*(size-1)
    size = np.ones(n_nodes, np.int64)
    size[1:] = (up_pos[1:] - down_pos[1:] - 1) // 2 + 1
    size[0] = n_nodes
    # depth: number of enclosing (down, up) intervals; equivalently
    # depth(c) = #down-arcs before down(c) minus #up-arcs before down(c)
    order = np.argsort(pos)
    delta = np.where(order % 2 == 0, 1, -1)  # even arc ids are "down"
    depth_at = np.cumsum(delta)  # depth after traversing the arc
    depth = np.zeros(n_nodes, np.int64)
    for c in range(1, n_nodes):
        depth[c] = depth_at[down_pos[c]]
    # rooting: parent = the other endpoint of the down arc
    parent = np.zeros(n_nodes, np.int64)
    for c in range(1, n_nodes):
        parent[c] = arcs[2 * (c - 1)][0]

    # verify against a BFS ground truth
    import collections
    adj = collections.defaultdict(list)
    for c in range(1, n_nodes):
        adj[parent[c]].append(c)
    truth_depth = np.zeros(n_nodes, np.int64)
    q = collections.deque([0])
    while q:
        u = q.popleft()
        for w in adj[u]:
            truth_depth[w] = truth_depth[u] + 1
            q.append(w)
    assert np.array_equal(depth, truth_depth), "depth mismatch"
    assert size[0] == n_nodes and (size >= 1).all()
    print(f"depth/subtree-size verified (max depth {depth.max()}, "
          f"mean subtree {size.mean():.1f})")
    print(f"list-ranking rounds: {stats['rounds'] // p}, "
          f"messages: {stats['chase_msgs']}")


if __name__ == "__main__":
    main()
