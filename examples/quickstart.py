"""Quickstart: rank a distributed list with the paper's algorithm.

  PYTHONPATH=src python examples/quickstart.py

Builds the paper's List(n, gamma) instance, runs sparse-ruling-set with
spawning (2 rounds + pointer-doubling base case, local contraction on,
reversal avoided via the §2.5 postprocess) on a device mesh, verifies
against the sequential oracle, and prints the stats that reproduce the
paper's analytical predictions.
"""
import os
import sys

# virtual PEs for the demo (must precede the jax import)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import math

import jax
import numpy as np

from repro import compat
from repro.core.listrank import (IndirectionSpec, ListRankConfig, analysis,
                                 instances, rank_list_seq,
                                 rank_list_with_stats)


def main():
    p = len(jax.devices())
    mesh = compat.make_mesh((2, p // 2), ("row", "col"))
    n = 1 << 16
    print(f"ranking a {n}-element random list on {p} PEs "
          f"(grid indirection {2}x{p // 2})")
    succ, rank = instances.gen_list(n, gamma=1.0, seed=0)

    cfg = ListRankConfig(srs_rounds=2, local_contraction=True,
                         ruler_fraction=1 / 32)
    succ_out, rank_out, stats = rank_list_with_stats(
        succ, rank, mesh, cfg=cfg,
        indirection=IndirectionSpec.grid(("row", "col")))

    s_ref, r_ref = rank_list_seq(succ, rank)
    assert np.array_equal(np.asarray(succ_out), s_ref)
    assert np.array_equal(np.asarray(rank_out), r_ref)
    print("matches the sequential oracle")

    r_total = p * max(4, int(n / p / 32))
    print(f"chase rounds:    {stats['rounds'] // p} "
          f"(paper predicts ~n/r+1 = {n / r_total + 1:.0f})")
    print(f"subproblem size: {stats['sub_size']} "
          f"(paper predicts ~r ln(n/r) = "
          f"{r_total * math.log(n / r_total):.0f})")
    print(f"chase messages:  {stats['chase_msgs']} "
          f"(2 hops x ~one per element)")
    print(f"r* from the cost model (SuperMUC constants): "
          f"{analysis.r_star(n, p, 2, analysis.SUPERMUC)}")

    # same run, parameters derived from the §2.6 cost model instead of
    # hand-set: ruler_fraction=None -> per-level r* (tuner.level_plan)
    auto = cfg.with_(ruler_fraction=None)
    _, rank_auto, stats_auto = rank_list_with_stats(
        succ, rank, mesh, cfg=auto,
        indirection=IndirectionSpec.grid(("row", "col")))
    assert np.array_equal(np.asarray(rank_auto), r_ref)
    print(f"auto-tuned (ruler_fraction=None): "
          f"rounds {stats_auto['rounds'] // p} vs {stats['rounds'] // p} "
          f"fixed, rulers {stats_auto['rulers']} vs {stats['rulers']}")


if __name__ == "__main__":
    main()
