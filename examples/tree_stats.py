"""Distributed tree statistics via the treealg subsystem — the paper's
motivating Euler-tour application, now a first-class engine instead of
a host-side postprocess (contrast examples/euler_tour.py, which derives
the same quantities by hand from a raw ranked tour).

  PYTHONPATH=src python examples/tree_stats.py

Builds a forest of random trees, constructs the Euler tours ON DEVICE
(two packed exchange rounds over the mesh), ranks both tour weightings
in ONE batched mesh solve, and reads depth / subtree size / preorder /
postorder for every node of every tree — then re-roots one tree and
verifies everything against a DFS oracle.
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import jax  # noqa: E402

from repro import compat  # noqa: E402
from repro.core import treealg  # noqa: E402
from repro.core.listrank import ListRankConfig, instances  # noqa: E402


def dfs_stats(parent):
    sys.setrecursionlimit(1000000)
    n = len(parent)
    children = [[] for _ in range(n)]
    for c in range(n):
        if parent[c] != c:
            children[parent[c]].append(c)
    depth = np.zeros(n, np.int64)
    size = np.ones(n, np.int64)
    pre = np.zeros(n, np.int64)
    post = np.zeros(n, np.int64)
    for r in [c for c in range(n) if parent[c] == c]:
        cp, cs = [0], [0]

        def dfs(u, d):
            depth[u] = d
            pre[u] = cp[0]
            cp[0] += 1
            for v in children[u]:
                dfs(v, d + 1)
                size[u] += size[v]
            post[u] = cs[0]
            cs[0] += 1

        dfs(r, 0)
    return depth, size, pre, post


def main():
    p = len(jax.devices())
    mesh = compat.make_mesh((p,), ("pe",))
    cfg = ListRankConfig(srs_rounds=2, local_contraction=True)

    # a batch of independent trees of mixed size/model — the serving
    # scenario: many small queries, one solver invocation
    sizes = [257, 1024, 93, 511, 2048]
    parents = [instances.gen_tree_parents(n, seed=i, locality=bool(i % 2))
               for i, n in enumerate(sizes)]
    print(f"forest of {len(sizes)} trees, {sum(sizes)} nodes, p={p}")

    stats_list = treealg.solve_forest(parents, mesh, cfg=cfg)
    solve = stats_list[0].stats
    print(f"one batched solve: attempts={solve['attempts']}, "
          f"chase rounds={solve['rounds'] // p}, "
          f"messages={solve['chase_msgs']}")
    for i, (q, st) in enumerate(zip(parents, stats_list)):
        d, s, pre, post = dfs_stats(q)
        assert np.array_equal(st.depth, d), f"depth mismatch tree {i}"
        assert np.array_equal(st.subtree_size, s), f"size mismatch {i}"
        assert np.array_equal(st.preorder, pre), f"preorder mismatch {i}"
        assert np.array_equal(st.postorder, post), f"postorder mismatch {i}"
        print(f"  tree {i}: n={q.shape[0]:5d} max depth={st.depth.max():3d} "
              f"mean subtree={st.subtree_size.mean():7.1f}  verified")

    # re-root the largest tree at its deepest node (edge orientation)
    big = int(np.argmax(sizes))
    deepest = int(np.argmax(stats_list[big].depth))
    newp = treealg.root_tree(parents[big], deepest, mesh, cfg=cfg)
    d2, _, _, _ = dfs_stats(newp)
    assert d2[deepest] == 0
    assert d2.max() >= stats_list[big].depth.max()
    print(f"re-rooted tree {big} at node {deepest}: new height {d2.max()} "
          f"(was {stats_list[big].depth.max()})  verified")
    print("tree_stats example OK")


if __name__ == "__main__":
    main()
