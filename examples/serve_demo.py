"""Serving demo: continuous batching over heterogeneous requests.

  PYTHONPATH=src python examples/serve_demo.py

Spins up the serving engine on a smoke-size gemma2-family model
(sliding-window + softcap attention exercised in the decode path),
submits a burst of requests larger than the slot pool, and reports
throughput + per-request latency percentiles.
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro import configs  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.serve.engine import Request, ServeConfig, ServingEngine  # noqa


def main():
    cfg = configs.get_config("gemma2-2b", smoke=True)
    params = M.init(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg,
                        ServeConfig(slots=4, max_seq=192,
                                    max_new_tokens=24, temperature=0.0))
    rng = np.random.default_rng(0)
    t_submit = {}
    t_done = {}
    for uid in range(10):
        plen = int(rng.integers(4, 48))
        eng.submit(Request(uid=uid, prompt=rng.integers(
            2, cfg.vocab_size, plen).astype(np.int32)))
        t_submit[uid] = time.time()

    done_before = set()
    t0 = time.time()
    ticks = 0
    while eng.queue or eng.active.any():
        eng.step(jax.random.PRNGKey(ticks))
        ticks += 1
        finished = {u for u, v in eng.out.items()
                    if v and u not in done_before
                    and u not in [eng.uid[s] for s in
                                  range(eng.scfg.slots) if eng.active[s]]}
        for u in finished - done_before:
            t_done[u] = time.time()
        done_before |= finished
    dt = time.time() - t0
    total = sum(len(v) for v in eng.out.values())
    lats = sorted(t_done.get(u, time.time()) - t_submit[u] for u in t_submit)
    print(f"requests: {len(eng.out)}  tokens: {total}  wall: {dt:.2f}s  "
          f"throughput: {total / dt:.1f} tok/s")
    print(f"latency p50/p90: {lats[len(lats) // 2]:.2f}s / "
          f"{lats[int(len(lats) * 0.9)]:.2f}s  ticks: {ticks}")


if __name__ == "__main__":
    main()
