"""Flight recorder demo: one traced list-ranking solve, end to end.

  PYTHONPATH=src python examples/trace_solve.py [trace.json]

Runs sparse-ruling-set on the simshard backend with the span tracer
attached, then prints the three artifacts the observability layer
produces for every solve:

  1. the span tree — prep/descend@k/base/ascend@k/post stage spans with
     their per-attempt children and wall timings;
  2. the model-vs-measured residual table — each stage's observed wall
     time next to its §2.6 predicted time (alpha/beta under the active
     MachineModel, collective footprint counted statically from the
     stage jaxpr);
  3. the capacity headroom report and measured-vs-modeled skew table —
     the device telemetry plane (cfg.telemetry=True): observed max
     mailbox fill vs compiled cap per stage/family/hop, and the
     per-hop destination skew vs the uniform model;
  4. the metrics registry — the solver's host stats ingested into one
     typed counter/gauge schema.

and finally writes a Chrome-trace-event JSON (drop it on
https://ui.perfetto.dev or chrome://tracing to browse the timeline).

Tracing is host-side only: the traced program is byte-identical with
the tracer on or off (asserted continuously by tests/test_obs.py).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core.listrank import (ListRankConfig, instances,  # noqa: E402
                                 rank_list_seq, rank_list_with_stats,
                                 sim_mesh)
from repro import obs  # noqa: E402


def main():
    out_path = sys.argv[1] if len(sys.argv) > 1 else "trace.json"
    p, n = 8, 1 << 14
    succ, rank = instances.gen_list(n, gamma=1.0, seed=0)
    cfg = ListRankConfig(algorithm="srs", srs_rounds=2,
                         local_contraction=True, telemetry=True)
    mesh = sim_mesh(p)

    tracer = obs.Tracer(meta={"name": "trace_solve", "n": n, "p": p})
    succ_out, rank_out, stats = rank_list_with_stats(
        succ, rank, mesh, cfg=cfg, seed=1, tracer=tracer)

    s_ref, r_ref = rank_list_seq(succ, rank)
    assert np.array_equal(np.asarray(succ_out), s_ref)
    assert np.array_equal(np.asarray(rank_out), r_ref)
    print(f"ranked n={n} on p={p} virtual PEs "
          f"({stats['attempts']} attempt(s)); matches the oracle\n")

    print("span tree:")
    for line in obs.span_tree_lines(tracer):
        print("  " + line)

    rows = obs.residual_rows(tracer)
    print()
    print(obs.format_residual_table(
        rows, title="model-vs-measured (§2.6, "
                    f"{cfg.machine.name} constants)"))
    summ = obs.residual_summary(rows)
    print(f"  total measured {summ['measured_s'] * 1e3:.2f}ms vs "
          f"predicted {summ['predicted_s'] * 1e6:.1f}us — large ratios "
          f"are expected here: the model prices network time on the "
          f"paper's machine, the measurement is single-CPU dispatch")

    tele = stats.get("telemetry", {})
    print()
    print(obs.format_headroom_table(tele.get("headroom", [])))

    from repro.core.listrank.exchange import MeshPlan  # noqa: E402
    from repro.obs import cost as cost_lib  # noqa: E402
    plan = MeshPlan.from_mesh(mesh, tuple(mesh.axis_names))
    print()
    print(obs.format_skew_table(
        obs.skew_rows(cost_lib.hop_sizes_of(plan), tele.get("stages", [])),
        title="measured-vs-modeled destination skew (uniform model)"))

    print("\nmetrics registry:")
    for metric in sorted(tracer.metrics, key=lambda m: m.name):
        snap = metric.snapshot()
        snap.pop("help", None)
        print(f"  {metric.name:<40} {metric.kind:<9} {snap}")

    obs.write_chrome_trace(tracer, out_path)
    print(f"\nwrote {out_path} — open it at https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
