"""End-to-end driver: train a ~100M-parameter model for a few hundred
steps with the full production stack (packed data pipeline, pjit'd
AdamW step with remat + scanned layers, fault-tolerant supervisor with
async checkpoints).

  PYTHONPATH=src python examples/train_100m.py [--steps 300]

Uses a ~100M llama-family config (a scaled tinyllama) on whatever
devices exist. On CPU this takes a while at the full size — pass
--tiny for a fast demonstration of the identical code path.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro.launch import train as train_main  # noqa: E402
from repro.models.params import count_params  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro import configs  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m")
    args = ap.parse_args()

    if args.tiny:
        # same code path, minutes not hours on CPU
        argv = ["--arch", "tinyllama-1.1b", "--smoke",
                "--steps", str(args.steps), "--batch", "8", "--seq", "128"]
    else:
        # ~100M llama-family config registered ad hoc
        import repro.configs.tinyllama_1_1b as tl
        cfg100 = tl.CONFIG.with_(
            name="llama-100m", num_layers=12, d_model=768, n_heads=12,
            n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32000,
            dtype=jax.numpy.float32)
        n = count_params(M.param_specs(cfg100))
        print(f"llama-100m: {n / 1e6:.1f}M params")
        configs._ARCHS["llama-100m"] = "tinyllama_1_1b"  # reuse module
        tl.SMOKE = cfg100  # serve via the smoke slot
        argv = ["--arch", "llama-100m", "--smoke",
                "--steps", str(args.steps), "--batch", "4", "--seq", "512"]
    argv += ["--ckpt-dir", args.ckpt_dir, "--log-every", "10",
             "--lr", "1e-3"]
    train_main.main(argv)


if __name__ == "__main__":
    main()
