"""Error-feedback int8 compressed gradient all-reduce under shard_map.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/dp_compression.py

Demonstrates the distributed-optimization trick from
repro.runtime.compression on a pure data-parallel loop: per-device
gradients are quantized to int8 blocks (+fp32 scales), summed across
the data axis, dequantized, with the quantization residual carried as
error feedback. Compares convergence against exact fp32 all-reduce —
the loss curves match to within noise while the gradient wire format
shrinks ~3.6x.
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import functools  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.runtime import compression  # noqa: E402


def main():
    p = len(jax.devices())
    mesh = compat.make_mesh((p,), ("data",))
    dim = 512
    rng = np.random.default_rng(0)
    w_true = jnp.asarray(rng.normal(size=(dim,)), jnp.float32)
    x_all = jnp.asarray(rng.normal(size=(p * 64, dim)), jnp.float32)
    y_all = x_all @ w_true

    def run(compressed: bool, steps=150, lr=0.05):
        w = jnp.zeros((dim,), jnp.float32)
        err0 = jnp.zeros((p, dim), jnp.float32)  # per-device residual

        @jax.jit
        @functools.partial(
            compat.shard_map, mesh=mesh,
            in_specs=(P(), P("data"), P("data"), P("data")),
            out_specs=(P(), P("data")))
        def step(w, x, y, err):
            pred = x @ w
            g = 2 * x.T @ (pred - y) / x.shape[0]
            if compressed:
                g, err = compression.compressed_psum(g, "data", err[0])
                g = g / p
                err = err[None]
            else:
                g = jax.lax.pmean(g, "data")
            return w - lr * g, err

        losses = []
        err = err0
        for _ in range(steps):
            w, err = step(w, x_all, y_all, err)
            losses.append(float(jnp.mean((x_all @ w - y_all) ** 2)))
        return losses

    exact = run(False)
    comp = run(True)
    print(f"final loss exact fp32 : {exact[-1]:.3e}")
    print(f"final loss int8+EF    : {comp[-1]:.3e}")
    wire_fp32 = 4 * 512
    wire_int8 = 512 + 4 * (512 // compression.BLOCK)
    print(f"gradient wire bytes: {wire_fp32} -> {wire_int8} "
          f"({wire_fp32 / wire_int8:.1f}x smaller)")
    assert comp[-1] < 1e-2, "compressed training failed to converge"


if __name__ == "__main__":
    main()
