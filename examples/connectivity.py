"""Distributed connectivity and spanning forests — the graphalg front
door: raw edge lists in, components + rooted forests + per-node tree
statistics out, with list ranking as the subroutine throughout.

  PYTHONPATH=src python examples/connectivity.py

Generates multi-component random graphs (GNM-like and RGG2D-like),
runs connected_components / spanning_forest / the end-to-end
graph_stats pipeline (hooking rounds -> unrooted Euler tour -> two
in-program list-ranking solves -> closed-form statistics, ONE jitted
mesh program), verifies against a host union-find, and answers
ancestor queries from the pre/postorder numbers without any further
communication.
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import jax  # noqa: E402

from repro import compat  # noqa: E402
from repro.core import graphalg, treealg  # noqa: E402
from repro.core.listrank import ListRankConfig, instances  # noqa: E402


def union_find(n, edges):
    parent = np.arange(n)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for a, b in edges:
        ra, rb = find(int(a)), find(int(b))
        if ra != rb:
            parent[max(ra, rb)] = min(ra, rb)
    return np.array([find(v) for v in range(n)])


def main():
    p = len(jax.devices())
    mesh = compat.make_mesh((p,), ("pe",))
    cfg = ListRankConfig(srs_rounds=1, local_contraction=True)

    n, e = 1 << 11, 1 << 12
    for fam, kw in [("gnm", dict(locality=False, num_components=6)),
                    ("rgg2d", dict(locality=True, num_components=4))]:
        edges = instances.gen_graph_edges(n, e, seed=42, **kw)
        labels, st = graphalg.connected_components(edges, n, mesh, cfg=cfg)
        assert np.array_equal(labels, union_find(n, edges)), fam
        print(f"{fam}: n={n} E={e} -> {np.unique(labels).size} components "
              f"in {st['cc_rounds']} hooking rounds "
              f"({st['cc_msgs']} messages), verified vs union-find")

    # end to end: edges -> rooted forest -> Euler tour -> statistics,
    # one jitted mesh program
    edges = instances.gen_graph_edges(n, e, seed=7, locality=True,
                                      num_components=3)
    gs = graphalg.graph_stats(edges, n, mesh, cfg=cfg)
    print(f"graph_stats: {gs.n_components} components, "
          f"max depth {gs.depth.max()}, attempts={gs.stats['attempts']}")

    # the emitted forest is a first-class treealg input
    st = treealg.tree_stats(gs.parent, mesh, cfg=cfg)
    assert np.array_equal(st.depth, gs.depth)
    assert np.array_equal(st.preorder, gs.preorder)
    print("treealg.tree_stats on the emitted forest: identical statistics")

    # ancestor queries are closed-form over pre/postorder — no solves
    rng = np.random.default_rng(0)
    u = rng.integers(0, n, 5)
    for x in u:
        lo, hi = gs.subtree_interval(int(x))
        anc = gs.is_ancestor(gs.parent[x], x)
        print(f"  node {x}: subtree preorder interval [{lo}, {hi}], "
              f"parent-is-ancestor={bool(anc)}")
        assert bool(anc)
    print("connectivity example OK")


if __name__ == "__main__":
    main()
