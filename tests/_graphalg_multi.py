"""Subprocess body for multi-PE graphalg tests (8 virtual devices).

Run as: python tests/_graphalg_multi.py — exits nonzero on any mismatch
against the union-find / DFS oracles. Must set XLA_FLAGS before jax.
The acceptance matrix: connected_components and spanning_forest
oracle-match a host union-find on GNM, RGG2D-like, multi-component and
single-edge/empty-graph instances on the 8-PE mesh, and graph_stats
matches per-node DFS recomputation end to end.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from _graph_oracles import check_spanning_forest, union_find_labels  # noqa: E402
from _tree_oracles import dfs_stats  # noqa: E402
from repro import compat  # noqa: E402
from repro.core import graphalg  # noqa: E402
from repro.core.listrank import ListRankConfig, instances  # noqa: E402


def main():
    mesh = compat.make_mesh((2, 4), ("row", "col"))
    cfg = ListRankConfig(srs_rounds=1, local_contraction=True)
    failures = 0

    def check(name, ok):
        nonlocal failures
        print(("OK  " if ok else "FAIL") + f" {name}")
        failures += 0 if ok else 1

    families = [
        ("gnm", 240, 400, dict(locality=False)),
        ("rgg2d", 240, 400, dict(locality=True)),
        ("gnm multi", 200, 260, dict(locality=False, num_components=6)),
        ("rgg2d multi", 200, 260, dict(locality=True, num_components=4)),
        ("single edge", 9, None, np.array([[7, 2]], np.int64)),
        ("empty", 16, None, np.zeros((0, 2), np.int64)),
    ]
    for name, n, e, kw in families:
        edges = (instances.gen_graph_edges(n, e, seed=len(name), **kw)
                 if e is not None else kw)
        ref = union_find_labels(n, edges)
        labels, st = graphalg.connected_components(edges, n, mesh, cfg=cfg)
        check(f"cc {name}", np.array_equal(labels, ref)
              and st["cc_unconverged"] == 0)
        parent, lab2, st2 = graphalg.spanning_forest(edges, n, mesh,
                                                     cfg=cfg)
        errs = check_spanning_forest(n, edges, parent, lab2)
        check(f"forest {name}", errs == [] and
              st2["forest_edges"] == n - np.unique(ref).size)
        if errs:
            print("   ", errs[0])

    # graph_stats end to end on the 8-PE mesh, incl. the query layer
    for name, n, e, kw in [("gnm", 220, 360, dict(locality=False)),
                           ("rgg2d multi", 180, 230,
                            dict(locality=True, num_components=5))]:
        edges = instances.gen_graph_edges(n, e, seed=5 + len(name), **kw)
        gs = graphalg.graph_stats(edges, n, mesh, cfg=cfg)
        depth, size, pre, post = dfs_stats(gs.parent)
        ok = (check_spanning_forest(n, edges, gs.parent,
                                    gs.components) == []
              and np.array_equal(gs.depth, depth)
              and np.array_equal(gs.subtree_size, size)
              and np.array_equal(gs.preorder, pre)
              and np.array_equal(gs.postorder, post))
        # spot-check the ancestor layer against parent walking
        rng = np.random.default_rng(1)
        us = rng.integers(0, n, 64)
        vs = rng.integers(0, n, 64)
        for u, v in zip(us, vs):
            w, anc = int(v), False
            while True:
                if w == u:
                    anc = True
                    break
                if gs.parent[w] == w:
                    break
                w = int(gs.parent[w])
            ok = ok and bool(gs.is_ancestor(u, v)) == anc
        check(f"graph_stats {name}", ok)

    print("failures:", failures)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
