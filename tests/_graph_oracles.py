"""Host-side graph oracles shared by the graphalg tests: a plain
union-find for connectivity, plus spanning-forest validation (the
forest must use real graph edges, be acyclic, and span exactly the
union-find components)."""
import numpy as np


def union_find_labels(n: int, edges) -> np.ndarray:
    """Canonical component labels (minimum member id) by union-find.

    Unions always hang the larger root under the smaller, so the root
    of every set is its minimum element — the same canonical labeling
    graphalg's min-label hooking converges to.
    """
    parent = np.arange(n, dtype=np.int64)

    def find(x):
        root = x
        while parent[root] != root:
            root = parent[root]
        while parent[x] != root:
            parent[x], x = root, parent[x]
        return root

    for a, b in np.asarray(edges, dtype=np.int64):
        ra, rb = find(int(a)), find(int(b))
        if ra != rb:
            parent[max(ra, rb)] = min(ra, rb)
    return np.array([find(v) for v in range(n)], dtype=np.int64)


def check_spanning_forest(n: int, edges, parent, labels) -> list[str]:
    """Validate an oriented spanning forest against the edge list.

    Returns a list of failure descriptions (empty = valid): every
    non-root parent link must be a real graph edge, each component must
    be rooted exactly at its minimum node id, the forest must be
    acyclic, and each tree must span its union-find component.
    """
    parent = np.asarray(parent, dtype=np.int64)
    labels = np.asarray(labels, dtype=np.int64)
    ref = union_find_labels(n, edges)
    errs = []
    if not np.array_equal(labels, ref):
        errs.append("labels != union-find labels")
    eset = {frozenset((int(a), int(b)))
            for a, b in np.asarray(edges, dtype=np.int64) if a != b}
    nodes = np.arange(n)
    nonroot = parent != nodes
    for v in nodes[nonroot]:
        if frozenset((int(v), int(parent[v]))) not in eset:
            errs.append(f"parent[{v}]={parent[v]} is not a graph edge")
            break
    if not np.array_equal(np.flatnonzero(~nonroot), np.unique(ref)):
        errs.append("roots != component minima")
    # acyclicity + spanning: every node must reach its component's
    # root in < n steps
    for v in range(n):
        w, steps = v, 0
        while parent[w] != w and steps <= n:
            w, steps = parent[w], steps + 1
        if steps > n:
            errs.append(f"cycle reachable from node {v}")
            break
        if w != ref[v]:
            errs.append(f"node {v} reaches root {w}, expected {ref[v]}")
            break
    return errs
