"""Per-kernel shape/dtype sweeps against the pure-jnp (and numpy)
oracles, in Pallas interpret mode (the assignment's required check)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.local_chase import ops as lc_ops, ref as lc_ref
from repro.kernels.ssd_scan import ops as ssd_ops, ref as ssd_ref

RNG = np.random.default_rng(0)


# ------------------------------------------------------------- local_chase
def _random_chains(b, m, seed):
    rng = np.random.default_rng(seed)
    succ = np.arange(m, dtype=np.int32).reshape(1, m).repeat(b, 0)
    for bb in range(b):
        perm = rng.permutation(m)
        for j in range(m - 1):
            if rng.random() < 0.8:
                succ[bb, perm[j]] = perm[j + 1]
    dist = rng.integers(0, 10, size=(b, m)).astype(np.int32)
    dist[succ == np.arange(m)] = 0
    return succ, dist


@pytest.mark.parametrize("b,m", [(1, 64), (2, 128), (4, 256), (1, 1000)])
def test_local_chase_shapes(b, m):
    succ, dist = _random_chains(b, m, 1 + b + m)
    steps = int(np.ceil(np.log2(m))) + 1
    s_ref, d_ref = lc_ref.sequential_chase_ref(succ, dist)
    s_pl, d_pl = lc_ops.local_chase(jnp.asarray(succ), jnp.asarray(dist),
                                    steps)
    np.testing.assert_array_equal(np.asarray(s_pl), s_ref)
    np.testing.assert_array_equal(np.asarray(d_pl), d_ref)


@pytest.mark.parametrize("dtype", [jnp.int32, jnp.float32])
def test_local_chase_dtypes(dtype):
    succ, dist = _random_chains(2, 128, 7)
    dist = jnp.asarray(dist, dtype)
    s_pl, d_pl = lc_ops.local_chase(jnp.asarray(succ), dist, 8)
    s_j, d_j = lc_ref.local_chase_ref(jnp.asarray(succ), dist, 8)
    np.testing.assert_array_equal(np.asarray(s_pl), np.asarray(s_j))
    np.testing.assert_allclose(np.asarray(d_pl), np.asarray(d_j), rtol=1e-6)


# --------------------------------------------------------- flash attention
ATTN_CASES = [
    # b, hq, hkv, lq, lk, d, kwargs
    (2, 4, 4, 128, 128, 64, {}),
    (1, 8, 2, 256, 256, 32, {}),
    (1, 4, 4, 200, 200, 32, {"window": 64}),
    (1, 4, 2, 128, 128, 32, {"softcap": 50.0}),
    (1, 4, 4, 96, 160, 32, {"causal": False}),
    (2, 8, 2, 1, 384, 64, {"q_offset": 383}),
    (2, 8, 4, 160, 224, 32, {"window": 96, "softcap": 30.0, "scale": 0.1}),
]


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_sweep(case):
    b, hq, hkv, lq, lk, d, kw = case
    q = jnp.asarray(RNG.normal(size=(b, hq, lq, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, hkv, lk, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, hkv, lk, d)), jnp.float32)
    o_ref = fa_ref.attention_ref(q, k, v, **kw)
    o_pl = fa_ops.flash_attention(
        q, k, v, kw.get("causal", True), kw.get("window"),
        kw.get("softcap"), kw.get("scale"), kw.get("q_offset", 0), True)
    np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_ref),
                               atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_flash_attention_dtypes(dtype, tol):
    q = jnp.asarray(RNG.normal(size=(1, 4, 64, 32)), dtype)
    k = jnp.asarray(RNG.normal(size=(1, 2, 64, 32)), dtype)
    v = jnp.asarray(RNG.normal(size=(1, 2, 64, 32)), dtype)
    o_ref = fa_ref.attention_ref(q, k, v)
    o_pl = fa_ops.flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(o_pl, np.float32),
                               np.asarray(o_ref, np.float32), atol=tol,
                               rtol=tol)


def test_flash_attention_grad_matches_ref():
    q = jnp.asarray(RNG.normal(size=(1, 4, 48, 16)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 2, 48, 16)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 2, 48, 16)), jnp.float32)
    g1 = jax.grad(lambda q: fa_ops.flash_attention(q, k, v).sum())(q)
    g2 = jax.grad(lambda q: fa_ref.attention_ref(q, k, v).sum())(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(lq=st.integers(1, 64), lk=st.integers(1, 96), hq=st.sampled_from([2, 4]),
       grp=st.sampled_from([1, 2]), window=st.one_of(st.none(),
                                                     st.integers(1, 64)))
def test_flash_attention_property(lq, lk, hq, grp, window):
    """Property: kernel == reference for arbitrary (unaligned) shapes."""
    if hq % grp:
        return
    d = 16
    q = jnp.asarray(RNG.normal(size=(1, hq, lq, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, hq // grp, lk, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, hq // grp, lk, d)), jnp.float32)
    o_ref = fa_ref.attention_ref(q, k, v, window=window)
    o_pl = fa_ops.flash_attention(q, k, v, True, window)
    np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_ref),
                               atol=2e-5, rtol=1e-4)


# ----------------------------------------------------------------- ssd scan
SSD_CASES = [
    # bt, l, h, g, n, p, chunk
    (2, 256, 4, 4, 16, 32, 64),
    (1, 128, 8, 2, 32, 16, 32),
    (1, 64, 2, 1, 8, 8, 64),
    (1, 96, 4, 2, 16, 16, 32),
]


def _ssd_inputs(bt, l, h, g, n, p, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(bt, l, h, p)) * 0.5, dtype)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(bt, l, h)), dtype)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(bt, l, g, n)) * 0.5, dtype)
    C = jnp.asarray(rng.normal(size=(bt, l, g, n)) * 0.5, dtype)
    D = jnp.asarray(rng.normal(size=(h,)), jnp.float32)
    return x, dt, A, B, C, D


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_scan_sweep(case):
    bt, l, h, g, n, p, chunk = case
    x, dt, A, B, C, D = _ssd_inputs(bt, l, h, g, n, p, seed=sum(case))
    y_ref = ssd_ref.ssd_ref(x, dt, A, B, C, D)
    y_pl = ssd_ops.ssd_scan(x, dt, A, B, C, D, chunk, True)
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref),
                               atol=1e-5, rtol=1e-4)


def test_ssd_decode_matches_scan():
    x, dt, A, B, C, D = _ssd_inputs(2, 32, 4, 2, 8, 16, seed=3)
    y_full, s_fin = ssd_ref.ssd_ref(x, dt, A, B, C, D, return_state=True)
    state = jnp.zeros_like(s_fin)
    outs = []
    for t in range(32):
        y, state = ssd_ops.ssd_decode_step(
            x[:, t], dt[:, t], A, B[:, t], C[:, t], D, state)
        outs.append(y)
    y_step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(s_fin),
                               atol=1e-4, rtol=1e-4)


def test_ssd_grad_path():
    x, dt, A, B, C, D = _ssd_inputs(1, 64, 2, 1, 8, 8, seed=4)
    g1 = jax.grad(lambda x: ssd_ops.ssd_scan(x, dt, A, B, C, None, 32,
                                             True).sum())(x)
    g2 = jax.grad(lambda x: ssd_ref.ssd_ref(x, dt, A, B, C, None).sum())(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5)
