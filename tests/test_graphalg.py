"""graphalg subsystem tests (single-device mesh; the 8-PE matrix runs
in tests/_subprocess_smoke.py suite "graphalg"): connected components and spanning forests
against a host union-find across the instance families, the end-to-end
graph_stats pipeline against per-node DFS recomputation and against
treealg on the emitted parent array, the closed-form ancestor/interval
query layer, and the pipeline's pinned collective footprint."""
import numpy as np
import pytest
from _graph_oracles import check_spanning_forest, union_find_labels
from _tree_oracles import dfs_stats

from repro import compat
from repro.core import graphalg, treealg
from repro.core.listrank import ListRankConfig, instances


def mesh1():
    return compat.make_mesh((1,), ("pe",))


CFG = ListRankConfig(srs_rounds=1, local_contraction=False)

#: name -> (n, E, gen kwargs): GNM-like, RGG2D-like, multi-component
#: variants of both, plus the degenerate single-edge/empty/singleton
#: corners the acceptance criteria call out.
FAMILIES = [
    ("gnm", 48, 80, dict(locality=False)),
    ("rgg2d", 48, 80, dict(locality=True)),
    ("gnm_multi", 60, 70, dict(locality=False, num_components=4)),
    ("rgg2d_multi", 60, 70, dict(locality=True, num_components=3)),
    ("tree", 33, 32, dict(locality=False)),
    ("sparse_multi", 24, 12, dict(locality=False, num_components=12)),
]


def family_edges(n, e, seed, kw):
    return instances.gen_graph_edges(n, e, seed=seed, **kw)


# --------------------------------------------------------------------------
# connected components vs union-find
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name,n,e,kw", FAMILIES)
def test_connected_components_matches_union_find(name, n, e, kw):
    edges = family_edges(n, e, seed=len(name), kw=kw)
    labels, stats = graphalg.connected_components(edges, n, mesh1(),
                                                  cfg=CFG)
    np.testing.assert_array_equal(labels, union_find_labels(n, edges))
    assert stats["attempts"] == 1
    assert stats["cc_unconverged"] == 0


def test_connected_components_degenerate_inputs():
    # empty graph: all singletons
    labels, _ = graphalg.connected_components(
        np.zeros((0, 2), np.int64), 5, mesh1(), cfg=CFG)
    np.testing.assert_array_equal(labels, np.arange(5))
    # single edge
    labels, _ = graphalg.connected_components(
        np.array([[3, 1]]), 5, mesh1(), cfg=CFG)
    np.testing.assert_array_equal(labels, [0, 1, 2, 1, 4])
    # self-loops and duplicates change nothing
    labels, _ = graphalg.connected_components(
        np.array([[2, 2], [3, 1], [1, 3], [3, 1]]), 4, mesh1(), cfg=CFG)
    np.testing.assert_array_equal(labels, [0, 1, 2, 1])


def test_rejects_bad_edges():
    with pytest.raises(ValueError, match="out of range"):
        graphalg.connected_components(np.array([[0, 9]]), 4, mesh1(),
                                      cfg=CFG)
    with pytest.raises(ValueError, match="\\(E, 2\\)"):
        graphalg.connected_components(np.zeros((3,), np.int64), 4, mesh1(),
                                      cfg=CFG)


# --------------------------------------------------------------------------
# spanning forest: real graph edges, min-id roots, spans the components
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name,n,e,kw", FAMILIES)
def test_spanning_forest_valid(name, n, e, kw):
    edges = family_edges(n, e, seed=7 + len(name), kw=kw)
    parent, labels, stats = graphalg.spanning_forest(edges, n, mesh1(),
                                                     cfg=CFG)
    assert check_spanning_forest(n, edges, parent, labels) == []
    assert stats["forest_edges"] == n - np.unique(labels).size


def test_spanning_forest_feeds_treealg():
    """The tentpole integration contract: the emitted parent array is a
    valid treealg input — solve_forest/tree_stats consume it directly,
    and root_tree re-roots a component of it."""
    edges = family_edges(40, 70, seed=11, kw=dict(locality=True))
    parent, labels, _ = graphalg.spanning_forest(edges, 40, mesh1(),
                                                 cfg=CFG)
    st = treealg.tree_stats(parent, mesh1(), cfg=CFG)
    d, s, pre, post = dfs_stats(parent)
    np.testing.assert_array_equal(st.depth, d)
    np.testing.assert_array_equal(st.preorder, pre)
    # re-root the (single) component at an arbitrary non-root node
    assert np.unique(labels).size == 1
    newp = treealg.root_tree(parent, 17, mesh1(), cfg=CFG)
    e_old = {frozenset((c, int(parent[c]))) for c in range(40)
             if parent[c] != c}
    e_new = {frozenset((c, int(newp[c]))) for c in range(40)
             if newp[c] != c}
    assert e_old == e_new and newp[17] == 17


# --------------------------------------------------------------------------
# graph_stats end to end
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name,n,e,kw", FAMILIES)
def test_graph_stats_matches_dfs(name, n, e, kw):
    edges = family_edges(n, e, seed=23 + len(name), kw=kw)
    gs = graphalg.graph_stats(edges, n, mesh1(), cfg=CFG)
    assert check_spanning_forest(n, edges, gs.parent, gs.components) == []
    depth, size, pre, post = dfs_stats(gs.parent)
    np.testing.assert_array_equal(gs.depth, depth)
    np.testing.assert_array_equal(gs.subtree_size, size)
    np.testing.assert_array_equal(gs.preorder, pre)
    np.testing.assert_array_equal(gs.postorder, post)


def test_graph_stats_matches_treealg_on_emitted_forest():
    """depth/subtree/pre/postorder of the one-program pipeline must be
    bit-identical to running treealg.tree_stats on the forest it
    emitted (two independent derivations of the same statistics)."""
    edges = family_edges(52, 90, seed=31, kw=dict(num_components=2))
    gs = graphalg.graph_stats(edges, 52, mesh1(), cfg=CFG)
    st = treealg.tree_stats(gs.parent, mesh1(), cfg=CFG)
    np.testing.assert_array_equal(gs.depth, st.depth)
    np.testing.assert_array_equal(gs.subtree_size, st.subtree_size)
    np.testing.assert_array_equal(gs.preorder, st.preorder)
    np.testing.assert_array_equal(gs.postorder, st.postorder)
    np.testing.assert_array_equal(gs.components, st.root_of)


def test_graph_stats_isolated_nodes():
    gs = graphalg.graph_stats(np.array([[5, 6]]), 8, mesh1(), cfg=CFG)
    np.testing.assert_array_equal(gs.components, [0, 1, 2, 3, 4, 5, 5, 7])
    np.testing.assert_array_equal(gs.parent, [0, 1, 2, 3, 4, 5, 5, 7])
    np.testing.assert_array_equal(gs.depth, [0, 0, 0, 0, 0, 0, 1, 0])
    np.testing.assert_array_equal(gs.subtree_size, [1, 1, 1, 1, 1, 2, 1, 1])
    np.testing.assert_array_equal(gs.preorder, [0, 0, 0, 0, 0, 0, 1, 0])
    np.testing.assert_array_equal(gs.postorder, [0, 0, 0, 0, 0, 1, 0, 0])


def test_graph_stats_query_layer():
    edges = family_edges(36, 50, seed=41, kw=dict(num_components=3))
    gs = graphalg.graph_stats(edges, 36, mesh1(), cfg=CFG)
    n = gs.n_nodes
    # reference ancestor matrix by parent walking
    ref = np.zeros((n, n), bool)
    for x in range(n):
        w = x
        while True:
            ref[w, x] = True
            if gs.parent[w] == w:
                break
            w = int(gs.parent[w])
    got = gs.is_ancestor(np.arange(n)[:, None], np.arange(n)[None, :])
    np.testing.assert_array_equal(got, ref)
    # subtree intervals: v in subtree(u) <=> pre[v] in [lo_u, hi_u]
    # (same component)
    lo, hi = gs.subtree_interval(np.arange(n))
    for u in range(n):
        inside = gs.same_component(u, np.arange(n)) & \
            (gs.preorder >= lo[u]) & (gs.preorder <= hi[u])
        np.testing.assert_array_equal(inside, ref[u])
    # component helpers
    assert gs.n_components == np.unique(gs.components).size
    np.testing.assert_array_equal(
        gs.component_size(np.arange(n)),
        np.bincount(gs.components, minlength=n)[gs.components])


@pytest.mark.parametrize("variant", ["unpacked", "doubling"])
def test_graph_stats_transport_and_algorithm_variants(variant):
    """The pipeline rides the exchange layer and the full solver, so
    the unpacked wire path and the pointer-doubling algorithm must
    produce the identical result."""
    cfg = (CFG.with_(wire_packing=False) if variant == "unpacked"
           else CFG.with_(algorithm="doubling"))
    edges = family_edges(30, 45, seed=2, kw=dict(locality=False))
    ref = graphalg.graph_stats(edges, 30, mesh1(), cfg=CFG)
    got = graphalg.graph_stats(edges, 30, mesh1(), cfg=cfg)
    np.testing.assert_array_equal(got.parent, ref.parent)
    np.testing.assert_array_equal(got.depth, ref.depth)
    np.testing.assert_array_equal(got.preorder, ref.preorder)


# --------------------------------------------------------------------------
# the coalescing invariant: pinned collective footprint
# --------------------------------------------------------------------------

def test_pipeline_collective_count_static():
    """Acceptance criterion: graph_stats runs as one jitted mesh
    program whose collective count is pinned by jaxpr inspection. The
    hooking/shortcut/solver loops are while_loops, so the traced
    count must be static — identical across instance sizes — and every
    mesh-crossing primitive must be accounted for."""
    mesh = mesh1()
    small = graphalg.pipeline_collective_footprint(
        family_edges(32, 48, seed=1, kw=dict(locality=False)), 32, mesh,
        cfg=CFG)
    large = graphalg.pipeline_collective_footprint(
        family_edges(128, 256, seed=2, kw=dict(locality=True,
                                               num_components=2)),
        128, mesh, cfg=CFG)
    assert {k: c for k, (c, _) in small.items()} \
        == {k: c for k, (c, _) in large.items()}
    assert small["all_to_all"][0] > 0
    # volume scales with the instance while the count stays flat
    assert large["all_to_all"][1] > small["all_to_all"][1]
    # the cc-only prefix traces strictly fewer collectives
    cc_only = graphalg.pipeline_collective_footprint(
        family_edges(32, 48, seed=1, kw=dict(locality=False)), 32, mesh,
        cfg=CFG, mode="cc")
    assert cc_only["all_to_all"][0] < small["all_to_all"][0]
