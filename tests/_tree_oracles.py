"""Shared per-node oracle for the treealg tests: explicit DFS with
ascending-id children (the tour's adjacency order). Used by
tests/test_treealg.py and the tests/_subprocess_smoke.py subprocess."""
import sys

import numpy as np


def dfs_stats(parent):
    """(depth, subtree_size, preorder, postorder) by recursive DFS."""
    sys.setrecursionlimit(1000000)
    n = len(parent)
    children = [[] for _ in range(n)]
    for c in range(n):
        if parent[c] != c:
            children[parent[c]].append(c)
    depth = np.zeros(n, np.int64)
    size = np.ones(n, np.int64)
    pre = np.zeros(n, np.int64)
    post = np.zeros(n, np.int64)
    for r in [c for c in range(n) if parent[c] == c]:
        cp, cs = [0], [0]

        def dfs(u, d):
            depth[u] = d
            pre[u] = cp[0]
            cp[0] += 1
            for v in children[u]:
                dfs(v, d + 1)
                size[u] += size[v]
            post[u] = cs[0]
            cs[0] += 1

        dfs(r, 0)
    return depth, size, pre, post
