"""Golden-record generator: the 8-device MESH side of the bit-identity
pin (run as a subprocess — the device count must be fixed before jax
imports).

    python tests/_golden_multi.py           # print records (slow test)
    python tests/_golden_multi.py --write   # (re)write tests/golden/

The committed ``tests/golden/*.json`` files are this script's output;
``tests/test_simshard_golden.py`` asserts the simshard backend
reproduces every byte of them in-process, and the slow lane re-runs
this script to revalidate the mesh side.
"""
import json
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from repro import compat  # noqa: E402
from repro.core.listrank import rank_list_with_stats  # noqa: E402

import _simshard_cases as cases_lib  # noqa: E402


def main():
    write = "--write" in sys.argv[1:]
    mesh = compat.make_mesh(cases_lib.SHAPE, cases_lib.AXES)
    if write:
        cases_lib.GOLDEN_DIR.mkdir(exist_ok=True)
    for name, succ, rank, cfg in cases_lib.golden_cases():
        s, r, stats = rank_list_with_stats(succ, rank, mesh, cfg=cfg)
        rec = cases_lib.case_record(s, r, stats)
        print("GOLDEN " + json.dumps({"name": name, **rec}, sort_keys=True))
        if write:
            (cases_lib.GOLDEN_DIR / f"{name}.json").write_text(
                json.dumps(rec, sort_keys=True, indent=1) + "\n")
    print("done")


if __name__ == "__main__":
    main()
