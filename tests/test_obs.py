"""Flight-recorder tests (``repro.obs``): span-tree shape, the
no-perturbation pins (tracer on == tracer off, byte for byte and
collective count for collective count), the metrics registry schema,
the Chrome-trace exporter, and the zero-cost disabled path.

Marked ``obs`` (fast lane); the real-device mesh half runs in
``tests/_subprocess_smoke.py`` suite ``obs``.
"""
import functools
import json

import numpy as np
import pytest

from _simshard_cases import AXES, SHAPE, case_record, golden_cases, load_golden
from repro import compat, obs
from repro.core import graphalg, treealg
from repro.core.listrank import (FaultSpec, ListRankConfig,
                                 SolveExhausted, instances, introspect,
                                 rank_list_seq, rank_list_with_stats,
                                 sim_mesh, tuner)
from repro.core.listrank.exchange import MeshPlan
from repro.core.listrank import api as api_lib
from repro.core.listrank import resume as resume_lib
from repro.core.listrank import transport as transport_lib
from repro.obs import trace as trace_lib
from repro.runtime.fault_tolerance import SolveSupervisor, SolveSupervisorConfig

from jax.sharding import PartitionSpec as P

pytestmark = pytest.mark.obs

CASES = {name: (s, r, cfg) for name, s, r, cfg in golden_cases()}


def mesh8():
    return sim_mesh(SHAPE, AXES)


def small_case():
    s, r = instances.gen_list(256, gamma=1.0, seed=7)
    return s, r, ListRankConfig(srs_rounds=2, local_contraction=False)


# --------------------------------------------------------------------------
# span-tree well-formedness
# --------------------------------------------------------------------------

def test_clean_solve_covers_every_scheduled_stage_exactly_once():
    s, r, cfg = small_case()
    tr = obs.Tracer()
    sf, rf, stats = rank_list_with_stats(s, r, mesh8(), cfg=cfg, seed=1,
                                         tracer=tr)
    s_ref, r_ref = rank_list_seq(s, r)
    assert np.array_equal(np.asarray(sf), s_ref)
    assert np.array_equal(np.asarray(rf), r_ref)

    labels = [st.label for st in resume_lib.schedule_for(
        cfg.with_(algorithm="srs"))]
    assert labels == ["prep", "descend@0", "descend@1", "base@2",
                      "ascend@1", "ascend@0", "post"]
    stage_spans = list(tr.find(cat="stage"))
    assert [sp.name for sp in stage_spans] == labels

    (solve,) = tr.find(cat="solve")
    assert solve.parent == -1 and solve.args["outcome"] == "ok"
    assert solve.args["backend"] == "simshard"
    for sp in stage_spans:
        assert sp.parent == solve.index
        # exactly one committed attempt nested under each stage
        kids = tr.children(sp)
        assert [k.cat for k in kids] == ["stage-attempt"]
        assert kids[0].name == f"{sp.name}#1"
        assert kids[0].args["outcome"] == "committed"
        assert kids[0].args["wall_s"] >= 0
    # every span closed, with sane interval nesting
    for sp in tr.spans:
        assert sp.t1 is not None and sp.t1 >= sp.t0
        if sp.parent >= 0:
            par = tr.spans[sp.parent]
            assert par.t0 <= sp.t0 and sp.t1 <= par.t1 + 1e-9


def test_attempts_annotated_with_prediction_and_footprint():
    s, r, cfg = small_case()
    tr = obs.Tracer()
    rank_list_with_stats(s, r, mesh8(), cfg=cfg, seed=1, tracer=tr)
    for att in tr.find(cat="stage-attempt"):
        assert att.args["predicted_s"] >= 0
        assert att.args["collective_count"] >= 0
        assert att.args["payload_bytes"] >= 0
    # the solve span carries the §2.6 whole-solve prediction
    (solve,) = tr.find(cat="solve")
    assert solve.args["predicted_solve_s"] > 0
    rows = obs.residual_rows(tr)
    assert {row["stage"] for row in rows} == {
        st.label for st in resume_lib.schedule_for(cfg.with_(algorithm="srs"))}
    assert all(np.isfinite(row["measured_s"]) for row in rows)
    # the table renders every row
    table = obs.format_residual_table(rows)
    for row in rows:
        assert row["stage"] in table


def test_overflow_retry_nests_under_its_stage_span():
    """An injected chase overflow at descend@0: the stage span stays
    open across the retry, so both attempts are its children — the
    first marked overflow, the second committed — with fault/retry
    instants in between."""
    s, r, cfg = CASES["list-g1-s1"]
    tr = obs.Tracer()
    sf, rf, stats = rank_list_with_stats(
        s, r, mesh8(), cfg=cfg, tracer=tr,
        inject=FaultSpec("overflow", stage="descend", level=0,
                         family="chase"))
    assert stats["attempts"] == 2
    (d0,) = tr.find(cat="stage", name="descend@0")
    kids = tr.children(d0)
    assert [k.name for k in kids] == ["descend@0#1", "descend@0#2"]
    assert kids[0].args["outcome"] == "overflow"
    assert kids[0].args["fatal"]["dropped"] > 0
    assert kids[1].args["outcome"] == "committed"
    assert kids[1].args["scales"].startswith("chase=2")
    # the other stages still ran exactly once
    for lbl in ("prep", "base@1", "ascend@0", "post"):
        (sp,) = tr.find(cat="stage", name=lbl)
        assert len(tr.children(sp)) == 1
    names = [i.name for i in tr.instants]
    assert "overflow:chase:descend@0" in names
    assert "escalate:descend@0" in names


def test_checkpoint_spans_appear_under_supervised_solve(tmp_path):
    s, r, cfg = CASES["list-g1-s1"]
    tr = obs.Tracer()
    sup = SolveSupervisor(SolveSupervisorConfig(ckpt_dir=str(tmp_path)))
    rank_list_with_stats(s, r, mesh8(), cfg=cfg, supervisor=sup, tracer=tr)
    saves = list(tr.find(cat="checkpoint"))
    assert saves and all(sp.name.startswith("ckpt-save@") for sp in saves)
    assert saves[0].parent >= 0  # nested inside the solve tree


# --------------------------------------------------------------------------
# no-perturbation pins: tracer on == tracer off
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", ("list-g1-s1", "escalate-s6"))
def test_golden_bytes_identical_with_tracing_on(name):
    """The committed mesh goldens (solve output hashes, escalation
    path, full counters) reproduce exactly with the tracer attached —
    including through the capacity-escalation retry ladder."""
    s, r, cfg = CASES[name]
    tr = obs.Tracer()
    sf, rf, stats = rank_list_with_stats(s, r, mesh8(), cfg=cfg, tracer=tr)
    assert case_record(sf, rf, stats) == load_golden(name)
    assert len(tr.spans) > 0  # the tracer really was recording


@pytest.mark.parametrize("p", (8, 256))
def test_stage_collective_counts_identical_tracer_on_off(p):
    """The live staged solve's per-stage traced collective counts
    (host_stats["stage_collectives"], derived from each stage jaxpr)
    are identical with and without the tracer, at small and large p."""
    n = 8 * p
    s, r = instances.gen_list(n, gamma=1.0, seed=9)
    cfg = ListRankConfig(srs_rounds=1, local_contraction=True)
    out = {}
    for tag, tr in (("off", None), ("on", obs.Tracer())):
        sf, rf, stats = rank_list_with_stats(
            s, r, sim_mesh(p), cfg=cfg, seed=1, stage_counters=True,
            tracer=tr, term_bound=1)
        out[tag] = (np.asarray(sf).tobytes(), np.asarray(rf).tobytes(),
                    stats["stage_collectives"],
                    {k: v for k, v in stats.items() if isinstance(v, int)})
    assert out["on"] == out["off"]
    assert any(dict(c).get("all_to_all", 0) > 0
               for _, c in out["on"][2])


@pytest.mark.parametrize("p", (8, 256))
def test_mesh_program_counts_unaffected_by_active_tracer(p):
    """Tracing the mesh-backend solver program (abstract p-device mesh,
    no devices) inside an open tracer span yields the same jaxpr
    collective counts as with no tracer anywhere in scope — the
    recorder adds zero collectives to the traced program."""
    import jax.numpy as jnp

    n = 4 * p
    m = n // p
    cfg = ListRankConfig(srs_rounds=1, local_contraction=True)
    am = compat.abstract_mesh((p,), ("pe",))
    plan = MeshPlan.from_mesh(am, ("pe",))
    specs = api_lib.build_specs(cfg, plan, m, n, term_bound=m)
    spec = P(("pe",))
    fn = functools.partial(api_lib._solve_sharded, plan=plan, cfg=cfg,
                           specs=specs, m=m)
    mapped = compat.shard_map(fn, mesh=am, in_specs=(spec, spec, P()),
                              out_specs=(spec, spec, P()), check_vma=False)
    args = (jnp.zeros(n, jnp.int32), jnp.zeros(n, jnp.int32), jnp.int32(0))

    baseline = introspect.collective_counts(mapped, *args)
    tr = obs.Tracer()
    with tr.span("solve", cat="solve"):
        with tr.span("descend@0", cat="stage"):
            traced = introspect.collective_counts(mapped, *args)
    assert traced == baseline
    assert baseline.get("all_to_all", 0) > 0


def test_disabled_tracer_allocates_no_spans(monkeypatch):
    """With tracing off every instrumentation site goes through
    NULL_TRACER; no Span object may be constructed anywhere in the
    solve/graphalg/treealg paths (near-zero disabled overhead)."""
    def boom(*a, **kw):
        raise AssertionError("Span allocated with tracing disabled")

    monkeypatch.setattr(trace_lib, "Span", boom)
    s, r, cfg = small_case()
    sf, rf, stats = rank_list_with_stats(s, r, mesh8(), cfg=cfg, seed=1)
    assert np.array_equal(np.asarray(rf), rank_list_seq(s, r)[1])
    edges = instances.gen_graph_edges(24, 30, seed=3)
    graphalg.connected_components(edges, 24, mesh8(), cfg=cfg)


# --------------------------------------------------------------------------
# front doors: graphalg / treealg spans
# --------------------------------------------------------------------------

def test_graphalg_frontdoor_traced():
    edges = instances.gen_graph_edges(48, 80, seed=3)
    cfg = ListRankConfig(srs_rounds=1, local_contraction=False)
    tr = obs.Tracer()
    labels, stats = graphalg.connected_components(edges, 48, mesh8(),
                                                  cfg=cfg, tracer=tr)
    (pipe,) = tr.find(cat="solve", name="graphalg:cc")
    assert pipe.args["outcome"] == "ok" and pipe.args["backend"] == "simshard"
    kids = tr.children(pipe)
    assert kids and kids[-1].args["outcome"] == "committed"
    assert kids[-1].args["predicted_s"] >= 0
    assert tr.metrics.get("graphalg/cc/cc_rounds").value > 0


def test_treealg_build_tour_traced():
    parent = np.array([0, 0, 0, 1, 1, 2, 5, 6], np.int32)
    cfg = ListRankConfig(srs_rounds=1, local_contraction=False)
    tr = obs.Tracer()
    treealg.build_tour(parent, mesh8(), cfg=cfg, tracer=tr)
    (tour,) = tr.find(cat="solve", name="build_tour")
    assert tour.args["outcome"] == "ok"
    kids = tr.children(tour)
    assert kids[-1].args["outcome"] == "committed"


# --------------------------------------------------------------------------
# metrics registry
# --------------------------------------------------------------------------

def test_metrics_registry_schema():
    reg = obs.MetricsRegistry()
    c = reg.counter("msgs", help="messages")
    c.inc().inc(3)
    assert reg.counter("msgs").value == 4
    with pytest.raises(ValueError):
        c.inc(-1)
    with pytest.raises(TypeError):
        reg.gauge("msgs")  # kind conflict is an error
    reg.gauge("depth").set(7)
    h = reg.histogram("wall")
    h.observe(1.0)
    h.observe(3.0)
    assert h.count == 2 and h.mean == 2.0 and h.min == 1.0 and h.max == 3.0
    reg.text("log").set("a;b")
    snap = reg.to_dict()
    assert snap["msgs"]["value"] == 4 and snap["wall"]["count"] == 2
    assert {m.kind for m in reg} == {"counter", "gauge", "histogram", "text"}
    json.dumps(snap)  # the snapshot is JSON-clean


def test_ingest_host_stats_types_and_help():
    s, r, cfg = small_case()
    _, _, stats = rank_list_with_stats(s, r, mesh8(), cfg=cfg, seed=1)
    reg = obs.MetricsRegistry()
    obs.ingest_host_stats(reg, stats)
    assert reg.get("solve/rounds").kind == "counter"
    assert reg.get("solve/rounds").help  # help sourced from srs.STAT_HELP
    assert reg.get("solve/max_queue").kind == "gauge"
    assert reg.get("solve/scales_log").kind == "text"
    assert reg.get("solve/stages_run").value == len(
        resume_lib.schedule_for(cfg.with_(algorithm="srs")))
    json.dumps(reg.to_dict())


def test_json_safe_stats_handles_solver_stats():
    s, r, cfg = CASES["list-g1-s1"]
    _, _, stats = rank_list_with_stats(s, r, mesh8(), cfg=cfg)
    out = obs.json_safe_stats(stats)
    json.dumps(out)  # tuples (stage_log), nested dicts (recovery) survive
    assert out["stage_log"] == list(stats["stage_log"])


# --------------------------------------------------------------------------
# exporter
# --------------------------------------------------------------------------

def test_chrome_trace_roundtrip(tmp_path):
    s, r, cfg = CASES["list-g1-s1"]
    tr = obs.Tracer(meta={"name": "roundtrip"})
    rank_list_with_stats(
        s, r, mesh8(), cfg=cfg, tracer=tr,
        inject=FaultSpec("overflow", stage="descend", level=0,
                         family="chase"))
    path = tmp_path / "trace.json"
    obs.write_chrome_trace(tr, path)
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    assert any(e["ph"] == "M" and e["name"] == "process_name" for e in evs)
    xs = [e for e in evs if e["ph"] == "X"]
    assert len(xs) == len(tr.spans)
    for e in xs:
        assert e["dur"] >= 0 and e["ts"] >= 0
    # spans export in begin order: timestamps are monotone nondecreasing
    ts = [e["ts"] for e in xs]
    assert ts == sorted(ts)
    # the injected fault shows up as a thread-scoped instant
    instants = [e for e in evs if e["ph"] == "i"]
    assert any(e["name"] == "overflow:chase:descend@0" for e in instants)
    assert all(e["s"] == "t" for e in instants)


def test_chrome_trace_null_tracer_and_empty_tree(tmp_path):
    """Exporter edge cases: the NullTracer, a tracer with no spans at
    all, and spans without counter samples all export valid
    Perfetto-loadable JSON (round-trips through json)."""
    for tracer in (trace_lib.NULL_TRACER, obs.Tracer()):
        doc = obs.chrome_trace(tracer)
        blob = json.dumps(doc)
        back = json.loads(blob)
        assert isinstance(back["traceEvents"], list)
        assert back["traceEvents"][0]["ph"] == "M"
        assert back["displayTimeUnit"] == "ms"
        assert not [e for e in back["traceEvents"] if e["ph"] == "C"]
    # spans but no counters: X events export, no C events
    tr = obs.Tracer(meta={"name": "edge"})
    with tr.span("solo", cat="stage"):
        pass
    path = tmp_path / "edge.json"
    obs.write_chrome_trace(tr, path)
    doc = json.loads(path.read_text())
    phs = [e["ph"] for e in doc["traceEvents"]]
    assert "X" in phs and "C" not in phs


def test_counter_tracks_interleave_with_fault_instants():
    """Counter samples and fault instants share the timeline: both
    export, counter events are time-sorted, and their timestamps land
    inside the span that emitted them."""
    tr = obs.Tracer()
    with tr.span("solve", cat="solve"):
        tr.instant("fault:injected", cat="fault")
        tr.counter("telemetry/util_max", 0.25)
        tr.instant("fault:recovered", cat="fault")
        tr.counter("telemetry/util_max", 0.75)
        tr.counter("telemetry/queue_hwm", 12.0)
    doc = obs.chrome_trace(tr)
    evs = doc["traceEvents"]
    cs = [e for e in evs if e["ph"] == "C"]
    instants = [e for e in evs if e["ph"] == "i"]
    assert len(cs) == 3 and len(instants) == 2
    assert [e["ts"] for e in cs] == sorted(e["ts"] for e in cs)
    assert {e["name"] for e in cs} == {"telemetry/util_max",
                                       "telemetry/queue_hwm"}
    assert all(e["args"]["value"] >= 0 for e in cs)
    (solve,) = [e for e in evs if e["ph"] == "X"]
    for e in cs + instants:
        assert solve["ts"] <= e["ts"] <= solve["ts"] + solve["dur"]
    json.dumps(doc)


def test_null_tracer_counter_is_noop():
    trace_lib.NULL_TRACER.counter("telemetry/util_max", 1.0)
    assert trace_lib.NULL_TRACER.counters == ()


def test_residual_summary_totals():
    s, r, cfg = small_case()
    tr = obs.Tracer()
    rank_list_with_stats(s, r, mesh8(), cfg=cfg, seed=1, tracer=tr)
    rows = obs.residual_rows(tr)
    summ = obs.residual_summary(rows)
    assert summ["stages"] == len(rows)
    assert summ["measured_s"] == pytest.approx(
        sum(row["measured_s"] for row in rows))
    assert summ["predicted_s"] == pytest.approx(
        sum(row["predicted_s"] for row in rows))


# --------------------------------------------------------------------------
# structured exhaustion rendering (satellite a)
# --------------------------------------------------------------------------

def test_exhaustion_error_renders_escalation_path():
    s, r, cfg = CASES["escalate-s6"]
    with pytest.raises(SolveExhausted) as ei:
        rank_list_with_stats(s, r, mesh8(), cfg=cfg, max_retries=1)
    msg = str(ei.value)
    assert "did not complete after 2 attempts" in msg
    assert "escalation path:" in msg
    # each attempt line is a tuner.format_scales rendering
    assert f"attempt 1: {ei.value.scales_log[0]}" in msg
    assert ei.value.scales_log[0] == tuner.format_scales(
        tuner.CapacityScales())
    assert "fatal stats of the failing attempt:" in msg
    for key, count in ei.value.fatal.items():
        if count:
            assert f"{key}={count}" in msg
    for fam in ei.value.families:
        assert fam in msg
