"""Telemetry-plane suite (`-m telemetry` fast lane).

Pins the two contracts DESIGN.md §13 promises:

1. **telemetry=False changes nothing** — the committed golden byte
   records (output hashes, attempts, scales_log, every integer counter)
   are reproduced by telemetry-ON solves after popping the telemetry
   key, i.e. the flag only *adds* outputs, it never perturbs the solve
   (the traced-collective-count pin lives in test_transport_audit.py);
2. **telemetry=True explains the run** — every scheduled stage of
   every paper family reports finite utilization, headroom rows stay
   within compiled caps on first-attempt-clean solves, escalations are
   cross-referenced in scales terms, and the host-half algebra (merge,
   aggregate, DKW back-test, skew table) is exact on synthetic input.
"""
import json

import numpy as np
import pytest

from _simshard_cases import AXES, SHAPE, case_record, golden_cases, load_golden
from repro.core.listrank import (ListRankConfig, instances,
                                 rank_list_with_stats, sim_mesh)
from repro.core.listrank import resume as resume_lib
from repro import obs
from repro.obs import cost as cost_lib
from repro.obs import telemetry as tele_lib

pytestmark = pytest.mark.telemetry


# --------------------------------------------------------------------------
# host-half algebra on synthetic records
# --------------------------------------------------------------------------

def test_merge_semantics():
    """MAX_KEYS leaves merge by max, everything else adds; None is the
    identity; keys are unioned (partial increments merge into a full
    stage_zero record)."""
    a = {"fill_max": np.float32(0.25), "rounds": np.int32(2),
         "sub": {"queue_hwm": np.int32(3)}}
    b = {"fill_max": np.float32(0.75), "rounds": np.int32(1),
         "hist": np.int32(7)}
    m = tele_lib.merge(a, b)
    assert float(m["fill_max"]) == 0.75          # max
    assert int(m["rounds"]) == 3                 # additive
    assert int(m["hist"]) == 7                   # union from b
    assert int(m["sub"]["queue_hwm"]) == 3       # union from a
    assert tele_lib.merge(None, a) is a
    assert tele_lib.merge(a, None) is a
    # merge(zero, x) == x for the canonical stage record shape
    z = tele_lib.stage_zero(2)
    w = tele_lib.merge(z, tele_lib.stage_zero(2))
    assert int(w["queue_hwm"]) == 0
    assert set(w) == set(z)


def test_stage_zero_shapes():
    tele = tele_lib.stage_zero(3)
    assert set(tele) == set(tele_lib.STAGE_FAMILIES) | {"queue_hwm"}
    for fam in tele_lib.STAGE_FAMILIES:
        rec = tele[fam]
        assert rec["fill_max"].shape == (3,)
        assert rec["hist"].shape == (tele_lib.HIST_BINS,)


def test_utilization_always_finite():
    """A stage that routed nothing reports zeros, never NaN/inf."""
    zero = tele_lib.json_tele(tele_lib.stage_zero(2))
    util = tele_lib.utilization(zero)
    assert util == {"util_max": 0.0, "util_mean": 0.0}
    busy = dict(zero)
    busy["chase"] = dict(zero["chase"], fill_max=[0.5, 1.25],
                         fill_mean_sum=[0.4, 0.8], rounds=2)
    util = tele_lib.utilization(busy)
    assert util["util_max"] == 1.25
    assert util["util_mean"] == pytest.approx((0.4 + 0.8) / 4)


def test_stage_record_roundtrip_and_headroom():
    tele = tele_lib.json_tele(tele_lib.stage_zero(1))
    tele["gather"] = dict(tele["gather"], fill_max=[0.5],
                          dest_frac_max=[0.2], rounds=3)
    tele["queue_hwm"] = 6
    rec = tele_lib.StageRecord(label="descend@0", kind="descend", level=0,
                               caps={"gather": (16,)}, queue_cap=24,
                               tele=tele)
    back = tele_lib.StageRecord.from_json(json.loads(
        json.dumps(rec.to_json())))
    assert (back.label, back.level, back.caps, back.queue_cap) == \
        ("descend@0", 0, {"gather": (16,)}, 24)
    rows = tele_lib.headroom_rows([rec], final_scales="chase=1,gather=2")
    by_fam = {r["family"]: r for r in rows}
    # families with rounds==0 are skipped; queue HWM gets its own row
    assert set(by_fam) == {"gather", "queue"}
    g = by_fam["gather"]
    assert (g["cap"], g["fill_max"], g["scale"]) == (16, 0.5, 2.0)
    assert g["headroom"] == pytest.approx(0.5)
    q = by_fam["queue"]
    assert (q["cap"], q["fill_max"]) == (24, 6 / 24)
    table = tele_lib.format_headroom_table(rows)
    assert "worst fill 0.500 of cap 16" in table
    assert tele_lib.format_headroom_table([]).startswith("(no telemetry")


def test_parse_scales():
    assert tele_lib.parse_scales("chase=1,sub=2,gather=1.5,graph=1") == \
        {"chase": 1.0, "sub": 2.0, "gather": 1.5, "graph": 1.0}
    # scales_log joins attempts with ";" — last occurrence wins
    assert tele_lib.parse_scales("chase=1,sub=1;chase=2,sub=1")["chase"] == 2.0
    assert tele_lib.parse_scales("") == {}


def test_dkw_backtest_synthetic():
    """Observed skew under the sampled bound -> ok; above it -> flagged."""
    tele = tele_lib.json_tele(tele_lib.stage_zero(2))
    tele["chase"] = dict(tele["chase"], dest_frac_max=[0.1, 0.9], rounds=1)
    rec = tele_lib.StageRecord(label="s", kind="descend", level=0,
                               caps={"chase": (8, 8)}, queue_cap=0,
                               tele=tele)
    rows = tele_lib.dkw_backtest([0.15, 0.15], sample_size=1024,
                                 hop_sizes=[8, 8], records=[rec])
    assert [r["hop"] for r in rows] == [0, 1]
    margin = tele_lib.dkw_margin(1024, 8)
    assert rows[0]["bound"] == pytest.approx(0.15 + margin)
    assert rows[0]["ok"] and not rows[1]["ok"]
    assert rows[1]["observed_frac"] == pytest.approx(0.9)


def test_skew_rows_against_uniform_model():
    tele = tele_lib.json_tele(tele_lib.stage_zero(1))
    tele["gather"] = dict(tele["gather"], dest_frac_max=[0.5], rounds=1)
    rec = tele_lib.StageRecord(label="s", kind="descend", level=0,
                               caps={"gather": (16,)}, queue_cap=0,
                               tele=tele)
    # accepts StageRecord objects and their to_json dicts alike
    for recs in ([rec], [rec.to_json()]):
        rows = obs.skew_rows((8,), recs)
        assert len(rows) == 1
        assert rows[0]["modeled_frac"] == pytest.approx(1 / 8)
        assert rows[0]["observed_frac"] == pytest.approx(0.5)
        assert rows[0]["skew"] == pytest.approx(4.0)
    assert "skew" in obs.format_skew_table(rows, title="t")


# --------------------------------------------------------------------------
# contract 1: telemetry ON reproduces the committed goldens byte-for-byte
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", ("list-g1-s1", "escalate-s6"))
def test_telemetry_on_matches_golden_bytes(name):
    """Solving with cfg.telemetry=True and popping the telemetry key
    reproduces the committed golden record exactly — hashes, attempts,
    scales_log, and every integer counter (incl. the 3-attempt
    escalation ladder of escalate-s6)."""
    case = {c[0]: c for c in golden_cases()}[name]
    _, succ, rank, cfg = case
    sf, rf, stats = rank_list_with_stats(
        succ, rank, sim_mesh(SHAPE, AXES), cfg=cfg.with_(telemetry=True),
        seed=0)
    tele = stats.pop("telemetry")
    assert case_record(sf, rf, stats) == load_golden(name)
    # ...and the popped plane is well-formed for the same solve
    assert tele["stages"] and tele["headroom"]
    for srec in tele["stages"]:
        assert np.isfinite(srec["util_max"])
        assert np.isfinite(srec["util_mean"])


# --------------------------------------------------------------------------
# contract 2: telemetry ON explains every family's run
# --------------------------------------------------------------------------

def _family_instances(n):
    yield "list_g0.0", instances.gen_list(n, gamma=0.0, seed=1)
    yield "list_g0.5", instances.gen_list(n, gamma=0.5, seed=1)
    yield "list_g1.0", instances.gen_list(n, gamma=1.0, seed=1)
    for fam, loc in (("euler_local", True), ("euler_random", False)):
        s, r, _ = instances.gen_euler_tour(n // 2 + 1, seed=1, locality=loc)
        yield fam, instances.pad_to_multiple(s, r, 8)[:2]


def test_all_families_report_finite_utilization():
    """Every scheduled stage of all five paper families produces a
    telemetry record with finite utilization; on first-attempt-clean
    solves the observed max fill stays within the compiled cap."""
    cfg = ListRankConfig(srs_rounds=2, local_contraction=True,
                         telemetry=True)
    sched = [st.label for st in resume_lib.schedule_for(cfg)]
    mesh = sim_mesh(8)
    for fam, (succ, rank) in _family_instances(512):
        _, _, stats = rank_list_with_stats(succ, rank, mesh, cfg=cfg,
                                           seed=1)
        tele = stats["telemetry"]
        labels = {s["label"] for s in tele["stages"]}
        assert not [lbl for lbl in sched if lbl not in labels], \
            (fam, sched, labels)
        assert all(np.isfinite(s["util_max"]) and np.isfinite(s["util_mean"])
                   for s in tele["stages"]), fam
        worst = max((r["fill_max"] for r in tele["headroom"]), default=0.0)
        if stats["attempts"] == 1:
            assert worst <= 1.0, (fam, worst)


def test_escalation_explained_in_scales_terms():
    """A capacity escalation shows up in the headroom report: the
    escalated family's final scale is >1 on the rows of the stage that
    overflowed, so scales_log entries are explained by observed fill."""
    succ, rank = instances.gen_list(512, gamma=1.0, seed=6)
    cfg = ListRankConfig(srs_rounds=1, local_contraction=True,
                         sub_capacity_slack=0.05, telemetry=True)
    _, _, stats = rank_list_with_stats(succ, rank, sim_mesh(8), cfg=cfg,
                                       seed=0)
    assert stats["attempts"] > 1
    scales = tele_lib.parse_scales(stats["scales_log"])
    escalated = [fam for fam, s in scales.items() if s > 1.0]
    assert escalated
    rows = stats["telemetry"]["headroom"]
    for fam in escalated:
        fam_rows = [r for r in rows if r["family"] == fam]
        assert fam_rows and all(r["scale"] > 1.0 for r in fam_rows)


def test_tracer_gets_utilization_annotations():
    """With a tracer attached, telemetry annotates the span tree: the
    committed attempt spans carry util_max/util_mean args and the
    tracer accumulates Perfetto counter samples that export as ph:'C'
    events."""
    succ, rank = instances.gen_list(512, gamma=1.0, seed=1)
    cfg = ListRankConfig(srs_rounds=2, local_contraction=True,
                         telemetry=True)
    tr = obs.Tracer(meta={"name": "tele-test"})
    rank_list_with_stats(succ, rank, sim_mesh(8), cfg=cfg, seed=1,
                         tracer=tr)
    annotated = [s for s in tr.spans if "util_max" in s.args]
    assert annotated
    assert all(np.isfinite(s.args["util_max"]) for s in annotated)
    assert any(name.startswith("telemetry/") for name, _, _ in tr.counters)
    doc = obs.chrome_trace(tr)
    cs = [e for e in doc["traceEvents"] if e["ph"] == "C"]
    assert cs and all(e["cat"] == "telemetry" for e in cs)


def test_metrics_ingest_telemetry():
    """Host-stats ingestion turns the telemetry block into typed
    metrics: stage count, utilization histograms, worst-fill gauge."""
    succ, rank = instances.gen_list(512, gamma=1.0, seed=1)
    cfg = ListRankConfig(srs_rounds=1, local_contraction=True,
                         telemetry=True)
    _, _, stats = rank_list_with_stats(succ, rank, sim_mesh(8), cfg=cfg,
                                       seed=1)
    reg = obs.MetricsRegistry()
    obs.ingest_host_stats(reg, stats)
    by_name = {m.name: m for m in reg}
    assert by_name["solve/telemetry/stages"].snapshot()["value"] > 0
    worst = by_name["solve/telemetry/worst_fill"].snapshot()["value"]
    assert np.isfinite(worst) and worst >= 0
    assert by_name["solve/telemetry/stage_util_max"].snapshot()["count"] > 0


def test_graph_family_telemetry_cc_mode():
    """graphalg front door: the hooking/tour capacities report under
    the 'graph' family and the pipeline record lands in host stats."""
    from _graph_oracles import union_find_labels
    from repro.core import graphalg
    cfg = ListRankConfig(srs_rounds=1, local_contraction=True,
                         telemetry=True)
    edges = instances.gen_graph_edges(120, 180, seed=37, num_components=3)
    labels, st = graphalg.connected_components(edges, 120, sim_mesh(8),
                                               cfg=cfg)
    np.testing.assert_array_equal(labels, union_find_labels(120, edges))
    tele = st["telemetry"]
    (rec,) = tele["stages"]
    assert rec["label"].startswith("graphalg:")
    assert int(rec["tele"]["graph"]["rounds"]) > 0
    assert np.isfinite(rec["util_max"])
    assert any(r["family"] == "graph" for r in tele["headroom"])


def test_telemetry_off_has_no_stats_key():
    succ, rank = instances.gen_list(256, gamma=1.0, seed=1)
    cfg = ListRankConfig(srs_rounds=1, local_contraction=True)
    _, _, stats = rank_list_with_stats(succ, rank, sim_mesh(8), cfg=cfg,
                                       seed=1)
    assert "telemetry" not in stats
