"""The in-process large-p regression matrix (simshard backend).

What the subprocess 8-device matrices could never do: execute the
solver at p = 64 and 256 — the regimes where the tuner's decisions
(per-level r*, the Corollary-1 SRS-vs-doubling switch, capacity
derivations that scale with hop size) actually change — in ONE process,
against the sequential oracle, across instance families x wire formats
x algorithms.

Compile economy: all families of a (p, wire, algorithm) cell share one
jitted program — ``term_bound`` is pinned to the per-PE maximum so the
capacity specs (the jit key) are instance-independent.

The heavy cross-product tests carry the ``matrix`` marker (dedicated CI
job; deselect with ``-m "not matrix"`` for the fast lane).
"""
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.listrank import (ListRankConfig, instances, introspect,
                                 rank_list_seq, rank_list_with_stats,
                                 sim_mesh, tuner)
from repro.core.listrank import api as api_lib
from repro.core.listrank.exchange import MeshPlan

P_SIZES = (8, 64, 256)
BASE = ListRankConfig(srs_rounds=1, local_contraction=False)


def _families(n: int):
    """All instance families at total size n (terminals self-looped)."""
    fams = {}
    fams["list-g1"] = instances.gen_list(n, gamma=1.0, seed=11)
    fams["random-lists"] = instances.gen_random_lists(
        n, num_lists=9, seed=12, weighted=True)
    for name, loc in (("gnm-tour", False), ("rgg2d-tour", True)):
        s, r, _ = instances.gen_euler_tour(n // 2 + 1, seed=13, locality=loc)
        fams[name] = instances.pad_to_multiple(s, r, n)[:2]
    return fams


@pytest.mark.matrix
@pytest.mark.parametrize("algorithm", ("srs", "doubling", "auto"))
@pytest.mark.parametrize("packed", (True, False), ids=("packed", "unpacked"))
@pytest.mark.parametrize("p", P_SIZES)
def test_large_p_matrix(p, packed, algorithm):
    n = max(512, 4 * p)
    cfg = BASE.with_(wire_packing=packed, algorithm=algorithm)
    mesh = sim_mesh(p)
    for fam, (succ, rank) in _families(n).items():
        s_ref, r_ref = rank_list_seq(succ, rank)
        s, r, stats = rank_list_with_stats(succ, rank, mesh, cfg=cfg,
                                           term_bound=n // p)
        assert np.array_equal(np.asarray(s), s_ref), (fam, p, stats)
        assert np.array_equal(np.asarray(r), r_ref), (fam, p, stats)


@pytest.mark.matrix
def test_cost_model_r_star_differs_at_large_p():
    """ruler_fraction=None must EXECUTE a different per-level r* at
    p=256 than at p=8 (tuner.level_plan through the live solve path,
    not just the unit-level derivation): r* grows with p, and at this n
    the p=256 plan saturates the 1/4 cap while p=8 stays below it."""
    n = 1 << 19
    cfg = BASE.with_(ruler_fraction=None)
    lp8 = tuner.level_plan(cfg, 8, 1, n)
    lp256 = tuner.level_plan(cfg, 256, 1, n)
    assert lp8[0].frac != lp256[0].frac
    assert lp8[0].r_total < lp256[0].r_total

    succ, rank = instances.gen_list(n, gamma=1.0, seed=21)
    s_ref, r_ref = rank_list_seq(succ, rank)
    fracs = {}
    for p, lp in ((8, lp8), (256, lp256)):
        mesh = sim_mesh(p)
        plan = MeshPlan.from_mesh(mesh, ("pe",))
        specs = api_lib.build_specs(cfg, plan, n // p, n, term_bound=1)
        # the spec the solve will run with carries the plan's fraction
        assert specs[0].ruler_frac == pytest.approx(lp[0].frac)
        s, r, stats = rank_list_with_stats(succ, rank, mesh, cfg=cfg,
                                           term_bound=1)
        assert np.array_equal(np.asarray(s), s_ref), (p, stats)
        assert np.array_equal(np.asarray(r), r_ref), (p, stats)
        fracs[p] = specs[0].ruler_frac
    assert fracs[8] != fracs[256]


@pytest.mark.parametrize("p", (8, 256))
@pytest.mark.parametrize("packed", (True, False), ids=("packed", "unpacked"))
def test_solver_collective_counts_mesh_vs_simshard(p, packed):
    """The simulated-collective markers keep the jaxpr pins meaningful:
    tracing the full solver program on an abstract p-device mesh and on
    the simshard backend yields IDENTICAL collective counts (trace
    only — no devices, no compile)."""
    import jax.numpy as jnp
    import functools
    from repro.core.listrank import transport as transport_lib

    n = 4 * p
    m = n // p
    cfg = BASE.with_(wire_packing=packed)

    am = compat.abstract_mesh((p,), ("pe",))
    plan_mesh = MeshPlan.from_mesh(am, ("pe",), None, wire_packing=packed)
    specs = api_lib.build_specs(cfg, plan_mesh, m, n, term_bound=m)
    spec = P(("pe",))
    fn = functools.partial(api_lib._solve_sharded, plan=plan_mesh, cfg=cfg,
                           specs=specs, m=m)
    mapped = compat.shard_map(fn, mesh=am, in_specs=(spec, spec, P()),
                              out_specs=(spec, spec, P()), check_vma=False)
    args = (jnp.zeros(n, jnp.int32), jnp.zeros(n, jnp.int32), jnp.int32(0))
    counts_mesh = introspect.collective_counts(mapped, *args)

    sm = sim_mesh(p)
    plan_sim = MeshPlan.from_mesh(sm, ("pe",), None, wire_packing=packed)
    fn_s = functools.partial(api_lib._solve_sharded, plan=plan_sim, cfg=cfg,
                             specs=specs, m=m)
    runner = transport_lib.device_run(sm, ("pe",), fn_s,
                                      in_specs=(spec, spec, P()),
                                      out_specs=(spec, spec, P()))
    counts_sim = introspect.collective_counts(runner, *args)
    assert counts_mesh == counts_sim
    assert counts_mesh.get("all_to_all", 0) > 0
