"""End-to-end behaviour: train a tiny model with the full production
loop (pipeline -> pjit step -> supervisor -> checkpoints) and check the
loss drops; resume mid-run; serve with continuous batching."""
import jax
import numpy as np
import pytest

from repro.launch import train as train_main
from repro.serve.engine import Request, ServeConfig, ServingEngine


def test_train_loss_decreases(tmp_path):
    losses = train_main.main([
        "--arch", "tinyllama-1.1b", "--smoke", "--steps", "40",
        "--batch", "8", "--seq", "64", "--lr", "3e-3",
        "--ckpt-dir", str(tmp_path), "--log-every", "5"])
    assert len(losses) >= 4
    first = np.mean([l for _, l in losses[:2]])
    last = np.mean([l for _, l in losses[-2:]])
    assert last < first, f"loss did not decrease: {first} -> {last}"


def test_train_resumes_from_checkpoint(tmp_path):
    train_main.main([
        "--arch", "tinyllama-1.1b", "--smoke", "--steps", "10",
        "--batch", "4", "--seq", "32", "--ckpt-dir", str(tmp_path),
        "--ckpt-every", "5"])
    # second invocation starts from step 10's checkpoint and extends
    losses = train_main.main([
        "--arch", "tinyllama-1.1b", "--smoke", "--steps", "14",
        "--batch", "4", "--seq", "32", "--ckpt-dir", str(tmp_path),
        "--ckpt-every", "5", "--log-every", "2"])
    steps = [s for s, _ in losses]
    assert min(steps) > 10, "did not resume from checkpoint"


def test_serving_continuous_batching():
    from repro import configs
    from repro.models import model as M
    cfg = configs.get_config("tinyllama-1.1b", smoke=True)
    params = M.init(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg,
                        ServeConfig(slots=2, max_seq=128, max_new_tokens=6))
    rng = np.random.default_rng(0)
    for uid in range(5):  # more requests than slots
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(2, cfg.vocab_size, 7)
                           .astype(np.int32)))
    out = eng.run_to_completion()
    assert len(out) == 5
    assert all(1 <= len(v) <= 6 for v in out.values())


def test_serving_matches_direct_decode():
    """Engine output (greedy) == hand-rolled prefill+decode loop."""
    from repro import configs
    from repro.models import model as M
    import jax.numpy as jnp
    cfg = configs.get_config("tinyllama-1.1b", smoke=True)
    params = M.init(jax.random.PRNGKey(0), cfg)
    prompt = np.asarray([5, 9, 17, 33, 2, 8], np.int32)

    eng = ServingEngine(params, cfg,
                        ServeConfig(slots=1, max_seq=64, max_new_tokens=5))
    eng.submit(Request(uid=0, prompt=prompt))
    got = eng.run_to_completion()[0]

    cache = M.init_cache(cfg, 1, 64)
    toks = jnp.asarray(prompt)[None]
    _, cache = M.prefill(params, {"tokens": toks}, cfg, cache)
    want = []
    cur = int(prompt[-1])
    pos = len(prompt) - 1
    for _ in range(5):
        lg, cache = M.decode_step(params, jnp.asarray([[cur]]), pos, cfg,
                                  cache)
        lg = lg[0, 0, :cfg.vocab_size]
        cur = int(jnp.argmax(lg))
        want.append(cur)
        pos += 1
    assert got == want
