"""Checkpointing + fault tolerance: atomic roundtrip, keep-k, async,
restart-after-crash resumes identically, preemption saves state."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.runtime.fault_tolerance import Supervisor, SupervisorConfig


def _state(val=0.0):
    return {"params": {"w": jnp.full((8,), val, jnp.float32),
                       "b": jnp.arange(4, dtype=jnp.int32)},
            "opt": {"m": jnp.zeros((8,), jnp.float32)}}


def test_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path, async_save=False)
    st = _state(3.5)
    ck.save(7, st)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), st)
    restored, step = ck.restore(None, like)
    assert step == 7
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_and_latest(tmp_path):
    ck = Checkpointer(tmp_path, keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        ck.save(s, _state(float(s)))
    dirs = sorted(os.listdir(tmp_path))
    assert dirs == ["step_00000003", "step_00000004"]
    assert ck.latest_step() == 4


def test_async_save_then_restore(tmp_path):
    ck = Checkpointer(tmp_path, async_save=True)
    ck.save(1, _state(1.0))
    ck.wait()
    assert ck.latest_step() == 1


def test_restore_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(tmp_path, async_save=False)
    ck.save(1, _state())
    bad = {"params": {"w": jax.ShapeDtypeStruct((9,), jnp.float32),
                      "b": jax.ShapeDtypeStruct((4,), jnp.int32)},
           "opt": {"m": jax.ShapeDtypeStruct((8,), jnp.float32)}}
    with pytest.raises(ValueError):
        ck.restore(None, bad)


# ------------------------------------------------------- supervisor
def _mk_supervisor(tmp_path, **kw):
    def init_state():
        return {"x": jnp.zeros((), jnp.float32)}, 0

    def restore_like():
        return {"x": jax.ShapeDtypeStruct((), jnp.float32)}

    cfg = SupervisorConfig(ckpt_dir=str(tmp_path), ckpt_every=5,
                           async_save=False, **kw)
    return Supervisor(cfg, init_state, restore_like)


def test_supervisor_completes_and_checkpoints(tmp_path):
    sup = _mk_supervisor(tmp_path)

    def step_fn(state, step):
        return {"x": state["x"] + 1}, {"loss": float(step)}

    state, step = sup.run(step_fn, 12)
    assert step == 12
    assert float(state["x"]) == 12
    assert sup.stats["checkpoints"] >= 2


def test_supervisor_restarts_after_crash(tmp_path):
    sup = _mk_supervisor(tmp_path)
    sup.inject_failure_at = 8

    calls = []

    def step_fn(state, step):
        calls.append(step)
        return {"x": state["x"] + 1}, {}

    state, step = sup.run(step_fn, 12)
    assert step == 12
    assert sup.stats["restarts"] == 1
    # steps 5..7 replayed after restoring the step-5 checkpoint
    assert calls.count(5) == 2 and calls.count(6) == 2
    assert float(state["x"]) == 12  # state identical to no-crash run


def test_supervisor_gives_up_after_max_restarts(tmp_path):
    sup = _mk_supervisor(tmp_path, max_restarts=1)

    def step_fn(state, step):
        raise RuntimeError("permafail")

    with pytest.raises(RuntimeError):
        sup.run(step_fn, 4)


def test_supervisor_preemption_saves(tmp_path):
    sup = _mk_supervisor(tmp_path)

    def step_fn(state, step):
        if step == 3:
            sup._preempted = True  # simulate SIGTERM mid-run
        return {"x": state["x"] + 1}, {}

    state, step = sup.run(step_fn, 100)
    assert sup.stats["preempted"]
    assert step == 4
    # a fresh supervisor resumes from the preemption checkpoint
    sup2 = _mk_supervisor(tmp_path)
    state2, step2 = sup2.run(lambda s, i: ({"x": s["x"] + 1}, {}), 6)
    assert step2 == 6
    assert float(state2["x"]) == 6


def test_straggler_detection(tmp_path):
    import time
    sup = _mk_supervisor(tmp_path)
    sup.cfg.straggler_factor  # exists

    def step_fn(state, step):
        if step == 10:
            time.sleep(0.25)
        else:
            time.sleep(0.005)
        return state, {}

    sup.run(step_fn, 12)
    assert sup.stats["stragglers"] >= 1
