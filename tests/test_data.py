"""Data pipeline: packing correctness (vs brute force), list-ranking
metadata, determinism; hypothesis on packing invariants."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import compat
from repro.data import packing, pipeline


def _docs(seed, n_docs=12, max_len=40):
    rng = np.random.default_rng(seed)
    return [rng.integers(2, 1000, rng.integers(1, max_len)).astype(np.int32)
            for _ in range(n_docs)]


def test_pack_roundtrip_tokens():
    docs = _docs(0)
    packed = packing.pack_documents(docs, row_len=64)
    # every document's tokens appear contiguously across its segments
    term, after = packing.segment_metadata(packed)
    doc_id, pos, rem = packing.token_metadata(packed, term, after)
    for d, doc in enumerate(docs):
        mask = doc_id == d
        got = packed.rows[mask]
        order = np.argsort(pos[mask])
        np.testing.assert_array_equal(got[order], doc)
        # positions are 0..len-1 and remaining counts down
        np.testing.assert_array_equal(np.sort(pos[mask]),
                                      np.arange(len(doc)))
        np.testing.assert_array_equal(
            np.sort(rem[mask])[::-1], np.sort(len(doc) - 1 - pos[mask])[::-1])


def test_segment_metadata_is_list_ranking():
    """The segment instance is a valid list-ranking input and the
    ranks equal tokens-after-segment."""
    docs = _docs(3)
    packed = packing.pack_documents(docs, row_len=32)
    term, after = packing.segment_metadata(packed)
    # terminal of every chain is the doc's last segment: 0 tokens after
    last = {}
    for s, d in enumerate(packed.segment_doc):
        last[d] = s
    for s, d in enumerate(packed.segment_doc):
        assert term[s] == last[d]
    # tokens after = sum of later segment lengths
    for d in last:
        segs = [s for s in range(len(packed.segment_doc))
                if packed.segment_doc[s] == d]
        for i, s in enumerate(segs):
            expect = sum(packed.segment_len[t] for t in segs[i + 1:])
            assert after[s] == expect


def test_distributed_matches_oracle():
    import jax
    mesh = compat.make_mesh((1,), ("pe",))
    docs = _docs(7, n_docs=30)
    packed = packing.pack_documents(docs, row_len=48)
    t1, a1 = packing.segment_metadata(packed)
    t2, a2 = packing.segment_metadata(packed, mesh=mesh)
    np.testing.assert_array_equal(t1, t2)
    np.testing.assert_array_equal(a1, a2)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), row_len=st.sampled_from([16, 32, 80]),
       n_docs=st.integers(1, 25))
def test_property_packing_conserves_tokens(seed, row_len, n_docs):
    docs = _docs(seed, n_docs=n_docs, max_len=3 * row_len)
    packed = packing.pack_documents(docs, row_len)
    total = sum(len(d) for d in docs)
    term, after = packing.segment_metadata(packed)
    doc_id, pos, rem = packing.token_metadata(packed, term, after)
    assert (doc_id >= 0).sum() == total
    assert packed.segment_len.sum() == total
    # each segment chain's rank decreases along the chain
    assert (after >= 0).all()


def test_pipeline_determinism_and_shapes():
    cfg = pipeline.DataConfig(vocab_size=1000, seq_len=64, global_batch=4)
    b1 = pipeline.global_batch(cfg, step=5)
    b2 = pipeline.global_batch(cfg, step=5)
    b3 = pipeline.global_batch(cfg, step=6)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].shape == (4, 64)
    assert b1["labels"].shape == (4, 64)
    assert (b1["labels"][b1["labels"] >= 0] < 1000).all()
