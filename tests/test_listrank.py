"""List-ranking correctness on a single-device mesh (full code path —
routing, spawning, recursion, contraction — with p=1 self-sends) plus
hypothesis property tests. Multi-PE runs live in test_listrank_multi."""
import jax
import numpy as np
import pytest
from _hypothesis_compat import HealthCheck, given, settings, st

from repro import compat
from repro.core.listrank import (IndirectionSpec, ListRankConfig, analysis,
                                 instances, rank_list_seq,
                                 rank_list_with_stats)


def mesh1():
    return compat.make_mesh((1,), ("pe",))


def run_and_check(succ, rank, cfg, **kw):
    s_ref, r_ref = rank_list_seq(succ, rank)
    s, r, stats = rank_list_with_stats(succ, rank, mesh1(), cfg=cfg, **kw)
    np.testing.assert_array_equal(np.asarray(s), s_ref)
    np.testing.assert_array_equal(np.asarray(r), r_ref)
    return stats


BASE = ListRankConfig(srs_rounds=1, local_contraction=False)
VARIANTS = {
    "srs1": BASE,
    "srs2": BASE.with_(srs_rounds=2),
    "srs1_contract": BASE.with_(local_contraction=True),
    "srs2_contract": BASE.with_(srs_rounds=2, local_contraction=True),
    "reversal": BASE.with_(avoid_reversal=False),
    "doubling": BASE.with_(algorithm="doubling"),
    "doubling_contract": BASE.with_(algorithm="doubling",
                                    local_contraction=True),
    "allgather_base": BASE.with_(base_case="allgather"),
    "nodedup": BASE.with_(dedup_requests=False),
    "pallas_contract": BASE.with_(local_contraction=True, use_pallas=True),
    "unpacked": BASE.with_(wire_packing=False),
    "unpacked_srs2": BASE.with_(srs_rounds=2, local_contraction=True,
                                wire_packing=False),
    "pallas_pack": BASE.with_(use_pallas_pack=True),
    "auto_tuned": BASE.with_(ruler_fraction=None),
    "auto_tuned_srs2": BASE.with_(ruler_fraction=None, srs_rounds=2,
                                  local_contraction=True),
}


@pytest.mark.parametrize("variant", list(VARIANTS))
def test_variants_random_list(variant):
    succ, rank = instances.gen_list(256, gamma=1.0, seed=3)
    run_and_check(succ, rank, VARIANTS[variant])


@pytest.mark.parametrize("gamma", [0.0, 0.3, 1.0])
def test_locality_instances(gamma):
    succ, rank = instances.gen_list(512, gamma=gamma, seed=5)
    run_and_check(succ, rank, BASE.with_(local_contraction=True))


def test_multilist_and_weighted():
    succ, rank = instances.gen_random_lists(512, num_lists=9, seed=7,
                                            weighted=True)
    stats = run_and_check(succ, rank, BASE.with_(srs_rounds=2,
                                                 local_contraction=True))
    assert stats["dropped"] == 0


def test_euler_tour_instance():
    succ, rank, arcs = instances.gen_euler_tour(200, seed=11, locality=True)
    succ, rank = instances.pad_to_multiple(succ, rank, 1)
    run_and_check(succ, rank, BASE.with_(local_contraction=True))


def test_float_weights():
    rng = np.random.default_rng(0)
    succ, _ = instances.gen_random_lists(128, num_lists=4, seed=13)
    w = rng.uniform(0.0, 2.0, 128).astype(np.float32)
    w[succ == np.arange(128)] = 0.0
    s_ref, r_ref = rank_list_seq(succ, w)
    s, r, _ = rank_list_with_stats(succ, w, mesh1(), cfg=BASE)
    np.testing.assert_array_equal(np.asarray(s), s_ref)
    np.testing.assert_allclose(np.asarray(r), r_ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("make", [
    lambda: instances.gen_list(256, gamma=1.0, seed=3),
    lambda: instances.gen_list(256, gamma=0.0, seed=4),
    lambda: instances.gen_random_lists(256, num_lists=7, seed=5,
                                       weighted=True),
])
def test_packed_unpacked_bit_identical(make):
    """The packed wire format must be a pure transport change: identical
    output bits to the unpacked exchange, on every instance."""
    succ, rank = make()
    for cfg in (BASE, BASE.with_(srs_rounds=2, local_contraction=True)):
        s_p, r_p, _ = rank_list_with_stats(succ, rank, mesh1(), cfg=cfg)
        s_u, r_u, _ = rank_list_with_stats(
            succ, rank, mesh1(), cfg=cfg.with_(wire_packing=False))
        np.testing.assert_array_equal(np.asarray(s_p), np.asarray(s_u))
        np.testing.assert_array_equal(
            np.asarray(r_p).view(np.int32), np.asarray(r_u).view(np.int32))


def test_singletons_only():
    n = 64
    succ = np.arange(n, dtype=np.int32)
    rank = np.zeros(n, np.int32)
    s, r, _ = rank_list_with_stats(succ, rank, mesh1(), cfg=BASE)
    np.testing.assert_array_equal(np.asarray(s), succ)
    np.testing.assert_array_equal(np.asarray(r), rank)


# --------------------------------------------------------------------- props
@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(n=st.integers(8, 200), nl=st.integers(1, 8), seed=st.integers(0, 999),
       srs_rounds=st.integers(1, 2), contract=st.booleans(),
       avoid_rev=st.booleans())
def test_property_random_forests(n, nl, seed, srs_rounds, contract,
                                 avoid_rev):
    nl = min(nl, n)
    succ, rank = instances.gen_random_lists(n, num_lists=nl, seed=seed)
    cfg = BASE.with_(srs_rounds=srs_rounds, local_contraction=contract,
                     avoid_reversal=avoid_rev)
    run_and_check(succ, rank, cfg)


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(n=st.integers(16, 128), gamma=st.floats(0.0, 1.0),
       seed=st.integers(0, 99))
def test_property_rank_is_permutation_distance(n, gamma, seed):
    """Invariant: on a single full list, the multiset of ranks is
    exactly {0..n-1} and succ is constant (the terminal)."""
    succ, rank = instances.gen_list(n, gamma=gamma, seed=seed)
    s, r, _ = rank_list_with_stats(succ, rank, mesh1(),
                                   cfg=BASE.with_(local_contraction=True))
    r = np.sort(np.asarray(r))
    np.testing.assert_array_equal(r, np.arange(n))
    assert len(np.unique(np.asarray(s))) == 1


def test_cost_model_sanity():
    m = analysis.SUPERMUC
    r = analysis.r_star(1 << 24, 1024, 2, m)
    assert 1024 <= r < (1 << 24)
    t_opt = analysis.t_model(1 << 24, 1024, r, 2, m)
    t_bad = analysis.t_model(1 << 24, 1024, 64 * r, 2, m)
    assert t_opt <= t_bad
    assert analysis.expected_rounds(1 << 20, 1 << 10) == pytest.approx(1025.0)


def test_retry_on_tiny_capacity():
    """Pathologically small capacities must retry, not fail/corrupt."""
    succ, rank = instances.gen_list(128, gamma=1.0, seed=1)
    cfg = BASE.with_(capacity_slack=0.1, min_capacity=1, queue_slack=1.0,
                     sub_capacity_slack=0.5)
    s_ref, r_ref = rank_list_seq(succ, rank)
    s, r, stats = rank_list_with_stats(succ, rank, mesh1(), cfg=cfg,
                                       max_retries=6)
    np.testing.assert_array_equal(np.asarray(s), s_ref)
    np.testing.assert_array_equal(np.asarray(r), r_ref)
