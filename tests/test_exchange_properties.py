"""Property-based coverage of the exchange layer's pure kernels.

Hypothesis round-trips for the :class:`WireFormat` pack/unpack pair
across leaf widths, dtypes and capacities, and ``compact_queue``
against a numpy oracle — wired through ``tests/_hypothesis_compat`` so
minimal environments (no hypothesis) still collect and skip cleanly.
Each property also has one example-based pin so the oracle logic runs
everywhere.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import HealthCheck, given, settings, st
from repro.core.listrank.exchange import WireFormat, compact_queue

WIRE_DTYPES = ("int32", "float32", "uint32", "bool", "int8", "int16",
               "uint8")


def _gen_leaf(rng: np.random.Generator, q: int, dtype: str, trail):
    shape = (q,) + tuple(trail)
    if dtype == "bool":
        return rng.integers(0, 2, shape).astype(np.bool_)
    if dtype == "float32":
        # arbitrary bit patterns (incl. NaNs/infs) must survive exactly
        return rng.integers(-2**31, 2**31, shape, dtype=np.int64).astype(
            np.int32).view(np.float32)
    info = np.iinfo(dtype)
    return rng.integers(info.min, int(info.max) + 1, shape,
                        dtype=np.int64).astype(dtype)


def _roundtrip(q: int, leaf_specs, seed: int):
    rng = np.random.default_rng(seed)
    payload = {f"k{i}": jnp.asarray(_gen_leaf(rng, q, dt, trail))
               for i, (dt, trail) in enumerate(leaf_specs)}
    valid = jnp.asarray(rng.integers(0, 2, q).astype(np.bool_))
    wf = WireFormat.from_payload(payload)
    assert wf.width == 1 + sum(int(np.prod(trail, dtype=np.int64)) or 1
                               for _, trail in leaf_specs)
    wire = wf.pack(payload, valid)
    assert wire.shape == (q, wf.width) and wire.dtype == jnp.int32
    # both unpack paths must round-trip: row-major unpack AND the
    # column-major unpack_cols the packed route hot path actually uses
    for path, (unpacked, got_valid) in (("unpack", wf.unpack(wire)),
                                        ("unpack_cols",
                                         wf.unpack_cols(wire.T))):
        np.testing.assert_array_equal(np.asarray(got_valid),
                                      np.asarray(valid), err_msg=path)
        for k, v in payload.items():
            got = np.asarray(unpacked[k])
            assert got.dtype == np.asarray(v).dtype, (path, k)
            # compare raw bits: float NaN payloads must round-trip
            np.testing.assert_array_equal(
                _bits(got), _bits(np.asarray(v)), err_msg=f"{path}/{k}")


def _bits(a: np.ndarray) -> np.ndarray:
    if a.dtype == np.bool_:
        return a.astype(np.int32)
    return a.view({4: np.int32, 2: np.int16, 1: np.int8}[a.itemsize])


@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(q=st.integers(min_value=1, max_value=33),
       leaves=st.lists(
           st.tuples(st.sampled_from(WIRE_DTYPES),
                     st.sampled_from([(), (1,), (2,), (3,), (2, 2)])),
           min_size=1, max_size=4),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_wireformat_roundtrip_property(q, leaves, seed):
    _roundtrip(q, leaves, seed)


def test_wireformat_roundtrip_examples():
    """Example pin of the same property (runs without hypothesis)."""
    _roundtrip(1, [("int32", ())], seed=0)
    _roundtrip(17, [("float32", (2,)), ("bool", ()), ("int8", (3,))], seed=1)
    _roundtrip(32, [("uint32", (2, 2)), ("int16", ())], seed=2)


def test_wireformat_rejects_unsupported_dtypes():
    payload = {"x": jnp.zeros(4, jnp.float16)}  # no sub-word float lane
    with pytest.raises(TypeError):
        WireFormat.from_payload(payload).pack(payload, jnp.ones(4, bool))


def _oracle_compact(frags, cap: int):
    """Numpy reference: valid rows packed front, in order, truncated."""
    keys = list(frags[0][0].keys())
    rows = {k: [] for k in keys}
    dests = []
    for pl, d, v in frags:
        for i in np.flatnonzero(np.asarray(v)):
            for k in keys:
                rows[k].append(np.asarray(pl[k])[i])
            dests.append(np.asarray(d)[i])
    n_valid = len(dests)
    out = {k: np.stack(rows[k][:cap]) if min(n_valid, cap) else
           np.zeros((0,) + np.asarray(frags[0][0][k]).shape[1:],
                    np.asarray(frags[0][0][k]).dtype)
           for k in keys}
    return out, np.asarray(dests[:cap]), min(n_valid, cap), \
        max(n_valid - cap, 0)


def _check_compact(frag_sizes, cap: int, seed: int):
    rng = np.random.default_rng(seed)
    frags = []
    for fq in frag_sizes:
        pl = {"a": jnp.asarray(rng.integers(-99, 99, fq), jnp.int32),
              "b": jnp.asarray(rng.normal(size=(fq, 2)).astype(np.float32))}
        d = jnp.asarray(rng.integers(0, 7, fq), jnp.int32)
        v = jnp.asarray(rng.integers(0, 2, fq).astype(np.bool_))
        frags.append((pl, d, v))
    out_pl, out_d, out_v, dropped = compact_queue(frags, cap)
    ref_pl, ref_d, n_kept, ref_dropped = _oracle_compact(frags, cap)
    assert int(dropped) == ref_dropped
    got_v = np.asarray(out_v)
    assert int(got_v.sum()) == n_kept
    assert got_v[:n_kept].all()  # packed to the front
    np.testing.assert_array_equal(np.asarray(out_d)[:n_kept], ref_d)
    for k in ref_pl:
        np.testing.assert_array_equal(np.asarray(out_pl[k])[:n_kept],
                                      ref_pl[k], err_msg=k)


@settings(max_examples=50, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(frag_sizes=st.lists(st.integers(min_value=1, max_value=24),
                           min_size=1, max_size=4),
       cap=st.integers(min_value=1, max_value=64),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_compact_queue_matches_numpy_oracle_property(frag_sizes, cap, seed):
    _check_compact(frag_sizes, cap, seed)


def test_compact_queue_matches_numpy_oracle_examples():
    _check_compact([5], cap=8, seed=3)          # all fit
    _check_compact([9, 4, 7], cap=6, seed=4)    # overflow drops the tail
    _check_compact([3, 3], cap=1, seed=5)       # cap smaller than a frag
