"""Optional-hypothesis shim (satellite of the exchange-layer PR).

Test modules import ``given/settings/strategies`` from here instead of
hard-importing ``hypothesis``, so the suite collects and runs in
minimal environments: with hypothesis installed the real library is
re-exported unchanged; without it, property tests are skipped
(pytest.importorskip semantics, but scoped to the @given tests instead
of nuking whole modules) while every example-based test still runs.
"""
try:
    from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class HealthCheck:  # noqa: D401 - attribute bag
        too_slow = "too_slow"
        data_too_large = "data_too_large"
        filter_too_much = "filter_too_much"

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (property test)")(fn)
        return deco

    class _Strategies:
        """Inert stand-ins; @given skips before they are ever drawn."""

        def __getattr__(self, name):
            def strategy(*args, **kwargs):
                return None
            return strategy

    st = _Strategies()
