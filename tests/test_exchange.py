"""Exchange-layer unit tests: packed wire format, sort-free compaction,
fused route_compact, dedup gather, and the one-collective-per-hop
guarantee (jaxpr inspection). Single-device (p=1 self-sends) — the
multi-PE device smoke runs in the consolidated subprocess driver
(tests/_subprocess_smoke.py, suite "exchange")."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.listrank import introspect
from repro.core.listrank.config import IndirectionSpec
from repro.core.listrank.exchange import (MeshPlan, WireFormat,
                                          compact_queue, remote_gather,
                                          route, route_compact,
                                          sort_and_group)
from repro.kernels.mailbox_pack import ops as mp_ops


def mesh1():
    return compat.make_mesh((1,), ("pe",))


def plan1(packed=True, pallas=False):
    return MeshPlan.from_mesh(mesh1(), ("pe",), wire_packing=packed,
                              pallas_pack=pallas)


def _payload(q, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "ia": jnp.asarray(rng.integers(-5, 100, q), jnp.int32),
        "fb": jnp.asarray(rng.normal(size=q), jnp.float32),
        "bc": jnp.asarray(rng.integers(0, 2, q), bool),
    }


# ------------------------------------------------------------------ wire
def test_wire_roundtrip_exact():
    q = 64
    payload = _payload(q)
    payload["fb"] = payload["fb"].at[0].set(jnp.nan).at[1].set(-0.0)
    valid = jnp.asarray(np.random.default_rng(1).integers(0, 2, q), bool)
    wf = WireFormat.from_payload(payload)
    assert wf.width == 4  # 3 scalar leaves + valid word
    wire = wf.pack(payload, valid)
    assert wire.dtype == jnp.int32 and wire.shape == (q, 4)
    out, valid2 = wf.unpack(wire)
    np.testing.assert_array_equal(np.asarray(valid2), np.asarray(valid))
    for k in payload:
        np.testing.assert_array_equal(
            np.asarray(out[k]).view(np.int32).reshape(-1),
            np.asarray(payload[k]).view(np.int32).reshape(-1))


def test_wire_rejects_wide_dtypes():
    with pytest.raises(TypeError):
        WireFormat.from_payload(
            {"x": jnp.zeros(4, jnp.float16)}).pack(
                {"x": jnp.zeros(4, jnp.float16)}, jnp.ones(4, bool))


# ------------------------------------------------------- sort primitives
def test_sort_and_group():
    key = jnp.asarray([3, 1, 3, 7, 1, 1, 2], jnp.int32)
    valid = jnp.asarray([1, 1, 1, 0, 1, 1, 1], bool)
    order, skey, pos, newrun = sort_and_group(key, valid, 100)
    np.testing.assert_array_equal(np.asarray(skey), [1, 1, 1, 2, 3, 3, 100])
    np.testing.assert_array_equal(np.asarray(pos), [0, 1, 2, 0, 0, 1, 0])
    np.testing.assert_array_equal(np.asarray(newrun),
                                  [1, 0, 0, 1, 1, 0, 1])
    # stability: equal keys keep input order
    np.testing.assert_array_equal(np.asarray(order)[:3], [1, 4, 5])


def test_compact_queue_sort_free():
    rng = np.random.default_rng(2)
    frags = []
    for i in range(3):
        q = int(rng.integers(3, 12))
        pl = {"x": jnp.asarray(rng.integers(0, 50, q), jnp.int32)}
        d = jnp.asarray(rng.integers(0, 4, q), jnp.int32)
        v = jnp.asarray(rng.integers(0, 2, q), bool)
        frags.append((pl, d, v))
    cap = 16
    opl, od, ov, dropped = compact_queue(frags, cap)
    # reference: valid rows in concatenation order, front-packed
    ref_x = np.concatenate([np.asarray(pl["x"])[np.asarray(v)]
                            for pl, _, v in frags])
    ref_d = np.concatenate([np.asarray(d)[np.asarray(v)]
                            for _, d, v in frags])
    n = len(ref_x)
    assert int(dropped) == max(0, n - cap)
    take = min(n, cap)
    np.testing.assert_array_equal(np.asarray(opl["x"])[:take], ref_x[:take])
    np.testing.assert_array_equal(np.asarray(od)[:take], ref_d[:take])
    np.testing.assert_array_equal(np.asarray(ov),
                                  np.arange(cap) < take)


def test_compact_queue_overflow_drops_tail():
    q = 10
    pl = {"x": jnp.arange(q, dtype=jnp.int32)}
    frag = (pl, jnp.zeros(q, jnp.int32), jnp.ones(q, bool))
    opl, _, ov, dropped = compact_queue([frag], 4)
    assert int(dropped) == 6
    np.testing.assert_array_equal(np.asarray(opl["x"]), [0, 1, 2, 3])
    assert int(jnp.sum(ov)) == 4


# ---------------------------------------------------------------- route
def _run_route(plan, cap, payload, dest, valid, track_src=False):
    def fn(*leaves):
        pl = dict(zip(sorted(payload.keys()), leaves[:-2]))
        d, dv, lo, st = route(plan, [cap], pl, leaves[-2], leaves[-1],
                              track_src=track_src)
        left = sum(jnp.sum(lv).astype(jnp.int32) for _, _, lv in lo)
        return d, dv, left
    keys = sorted(payload.keys())
    args = [payload[k] for k in keys] + [dest, valid]
    m = compat.shard_map(fn, mesh1(),
                         in_specs=tuple(P("pe") for _ in args),
                         out_specs=(
                             {k: P("pe") for k in keys + (
                                 ["src"] if track_src else [])},
                             P("pe"), P()))
    return m(*args)


@pytest.mark.parametrize("packed", [True, False])
def test_route_p1_delivery_and_leftovers(packed):
    q, cap = 12, 5
    payload = _payload(q, seed=3)
    dest = jnp.zeros(q, jnp.int32)
    valid = jnp.asarray([1, 0, 1, 1, 1, 1, 0, 1, 1, 1, 1, 1], bool)
    d, dv, left = _run_route(plan1(packed), cap, payload, dest, valid)
    # p=1: the first `cap` valid messages are delivered in input order
    sel = np.flatnonzero(np.asarray(valid))[:cap]
    assert int(jnp.sum(dv)) == cap
    assert int(left) == int(np.sum(np.asarray(valid))) - cap
    for k in payload:
        np.testing.assert_array_equal(
            np.asarray(d[k])[np.asarray(dv)],
            np.asarray(payload[k])[sel])


def test_route_packed_unpacked_bit_identical():
    q, cap = 20, 32
    payload = _payload(q, seed=4)
    dest = jnp.zeros(q, jnp.int32)
    valid = jnp.asarray(np.random.default_rng(5).integers(0, 2, q), bool)
    outs = []
    for packed in (True, False):
        d, dv, _ = _run_route(plan1(packed), cap, payload, dest, valid,
                              track_src=True)
        outs.append((d, dv))
    (d1, v1), (d2, v2) = outs
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    for k in d1:
        np.testing.assert_array_equal(
            np.asarray(d1[k]).view(np.int32), np.asarray(d2[k]).view(np.int32))
    assert int(jnp.sum(jnp.where(v1, d1["src"], 0))) == 0  # p=1 => PE 0


# ------------------------------------------------- collective accounting
@pytest.mark.parametrize("packed,per_hop", [(True, 1), (False, 5)])
def test_route_collectives_per_hop(packed, per_hop):
    """Acceptance: packed route = exactly one all_to_all per hop. The
    unpacked path pays one per payload leaf (+dest +valid)."""
    q, cap = 8, 8
    payload = _payload(q)
    keys = sorted(payload.keys())

    for mesh, axes, ind, hops in [
            (mesh1(), ("pe",), None, 1),
            (compat.make_mesh((1, 1), ("row", "col")), ("row", "col"),
             IndirectionSpec.grid(("row", "col")), 2)]:
        plan = MeshPlan.from_mesh(mesh, axes, ind, wire_packing=packed)

        def fn(*leaves):
            pl = dict(zip(keys, leaves[:-2]))
            d, dv, _, _ = route(plan, [cap] * hops, pl, leaves[-2],
                                leaves[-1])
            return d, dv

        args = [payload[k] for k in keys] + [
            jnp.zeros(q, jnp.int32), jnp.ones(q, bool)]
        m = compat.shard_map(fn, mesh,
                             in_specs=tuple(P(axes) for _ in args),
                             out_specs=({k: P(axes) for k in keys}, P(axes)))
        counts = introspect.collective_counts(m, *args)
        assert counts.get("all_to_all", 0) == per_hop * hops, counts


def test_route_compact_matches_route_plus_compact():
    """Fused compaction must agree with route + compact_queue on p=1
    (single bucket => bucket order is input order on both paths)."""
    q, cap, qc = 14, 4, 14
    payload = _payload(q, seed=6)
    dest = jnp.zeros(q, jnp.int32)
    valid = jnp.ones(q, bool)
    keys = sorted(payload.keys())
    plan = plan1(True)

    def fused(*leaves):
        pl = dict(zip(keys, leaves[:-2]))
        d, dv, (qpl, qd, qv), dropped, _ = route_compact(
            plan, [cap], [(pl, leaves[-2], leaves[-1])], qc)
        return d, dv, qpl, qd, qv, dropped

    def legacy(*leaves):
        pl = dict(zip(keys, leaves[:-2]))
        d, dv, lo, _ = route(plan, [cap], pl, leaves[-2], leaves[-1])
        qpl, qd, qv, dropped = compact_queue(lo, qc)
        return d, dv, qpl, qd, qv, dropped

    args = [payload[k] for k in keys] + [dest, valid]
    specs = tuple(P("pe") for _ in args)
    ospec = ({k: P("pe") for k in keys}, P("pe"),
             {k: P("pe") for k in keys}, P("pe"), P("pe"), P())
    a = compat.shard_map(fused, mesh1(), in_specs=specs, out_specs=ospec)(*args)
    b = compat.shard_map(legacy, mesh1(), in_specs=specs, out_specs=ospec)(*args)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# -------------------------------------------------------- remote gather
@pytest.mark.parametrize("packed", [True, False])
@pytest.mark.parametrize("dedup", [True, False])
def test_remote_gather_p1(packed, dedup):
    q = 16
    rng = np.random.default_rng(7)
    targets = jnp.asarray(rng.integers(0, 8, q), jnp.int32)  # duplicates
    valid = jnp.asarray(rng.integers(0, 2, q), bool)
    plan = plan1(packed)

    def lookup_fn(g, v):
        return {"val": g * 2 + 1, "flag": v}

    def fn(t, v):
        out, answered, st = remote_gather(
            plan, t, v, lambda g: jnp.zeros_like(g), lookup_fn,
            req_cap=q, resp_cap=q, dedup=dedup)
        return out, answered

    m = compat.shard_map(fn, mesh1(), in_specs=(P("pe"), P("pe")),
                         out_specs=({"val": P("pe"), "flag": P("pe")},
                                    P("pe")))
    out, answered = m(targets, valid)
    np.testing.assert_array_equal(np.asarray(answered), np.asarray(valid))
    got = np.asarray(out["val"])[np.asarray(valid)]
    want = np.asarray(targets)[np.asarray(valid)] * 2 + 1
    np.testing.assert_array_equal(got, want)


def test_route_rejects_reserved_payload_keys():
    plan = plan1(True)
    with pytest.raises(ValueError):
        route(plan, [4], {"_dest": jnp.zeros(4, jnp.int32)},
              jnp.zeros(4, jnp.int32), jnp.ones(4, bool))
    with pytest.raises(ValueError):
        route(plan, [4], {"src": jnp.zeros(4, jnp.int32)},
              jnp.zeros(4, jnp.int32), jnp.ones(4, bool), track_src=True)


# ----------------------------------------------------- request / reply
def test_request_reply_owner_computed_addressing():
    """request_reply: the owner regroups the delivered batch and
    addresses its own replies (the euler.py / graphalg round shape).
    p=1 self-sends make the data flow fully checkable."""
    from repro.core.listrank.exchange import request_reply
    plan = plan1()
    q = 16
    slot = jnp.arange(q, dtype=jnp.int32)
    val = jnp.asarray(np.random.default_rng(0).integers(0, 50, q),
                      jnp.int32)
    valid = slot % 3 != 0

    def reply_fn(dlv, dval):
        # owner doubles the value and addresses the requester's slot
        aux = jnp.sum(dval).astype(jnp.int32)
        return ({"slot": dlv["slot"], "twice": 2 * dlv["val"]},
                jnp.zeros_like(dlv["slot"]), dval, aux)

    def fn(slot, val, valid):
        rdel, rval, aux, st = request_reply(
            plan, 16, 16, {"slot": slot, "val": val},
            jnp.zeros(q, jnp.int32), valid, reply_fn)
        out = jnp.zeros(q, jnp.int32).at[
            jnp.where(rval, rdel["slot"], q)].set(rdel["twice"],
                                                  mode="drop")
        return out, aux, st["leftover"], st["sent"]

    m = compat.shard_map(fn, mesh1(),
                         in_specs=(P("pe"), P("pe"), P("pe")),
                         out_specs=(P("pe"), P(), P(), P()),
                         check_vma=False)
    out, aux, leftover, sent = m(slot, val, valid)
    np.testing.assert_array_equal(
        np.asarray(out), np.where(np.asarray(valid), 2 * np.asarray(val),
                                  0))
    assert int(aux) == int(np.sum(np.asarray(valid)))  # aux passthrough
    assert int(leftover) == 0
    assert int(sent) == 2 * int(np.sum(np.asarray(valid)))
    # the two legs cost exactly one packed collective each
    counts = introspect.collective_counts(m, slot, val, valid)
    assert counts.get("all_to_all", 0) == 2


# ------------------------------------------------- payload accounting
def test_route_collective_payload_bytes_exact():
    """The coalescing invariant, sharpened: the packed hop's single
    all_to_all must ship exactly width * hop_size * cap int32 words —
    per-collective payload bytes catch a hidden extra word-plane that
    the op count alone would miss."""
    q, cap = 8, 8
    payload = _payload(q)
    keys = sorted(payload.keys())
    plan = plan1()

    def fn(*leaves):
        pl = dict(zip(keys, leaves[:-2]))
        d, dv, _, _ = route(plan, [cap], pl, leaves[-2], leaves[-1])
        return d, dv

    args = [payload[k] for k in keys] + [
        jnp.zeros(q, jnp.int32), jnp.ones(q, bool)]
    m = compat.shard_map(fn, mesh1(),
                         in_specs=tuple(P("pe") for _ in args),
                         out_specs=({k: P("pe") for k in keys}, P("pe")))
    fp = introspect.collective_footprint(m, *args)
    width = WireFormat.for_leaves(
        {**{k: payload[k].dtype for k in keys}, "_dest": jnp.int32}).width
    assert fp["all_to_all"] == (1, width * 1 * cap * 4), fp


#: (all_to_all count, all_to_all payload bytes) of the fixed solve
#: config below — the committed coalescing baseline. The count is the
#: number of packed hops the traced program contains (while_loop bodies
#: count once); the bytes are their summed wire matrices. Both are
#: functions of our routing code and the host-derived capacities only,
#: so any change here is a real change to the wire protocol.
PINNED_SOLVE_FOOTPRINT = (9, 59200)


def solve_footprint(n, mesh, cfg):
    """Collective (count, bytes) footprint of the traced solver
    program for an n-element instance (test_treealg pins counts only;
    this adds the payload-volume dimension)."""
    import functools
    from repro.core.listrank import api as api_lib
    plan = MeshPlan.from_mesh(mesh, ("pe",), None,
                              wire_packing=cfg.wire_packing)
    specs = api_lib.build_specs(cfg, plan, n // plan.p, n, term_bound=8)
    fn = functools.partial(api_lib._solve_sharded, plan=plan, cfg=cfg,
                           specs=specs, m=n // plan.p)
    m = compat.shard_map(fn, mesh, in_specs=(P("pe"), P("pe"), P()),
                         out_specs=(P("pe"), P("pe"), P()),
                         check_vma=False)
    succ = jnp.arange(n, dtype=jnp.int32)
    rank = jnp.zeros(n, jnp.int32)
    return introspect.collective_footprint(m, succ, rank, jnp.int32(0))


def test_solver_collective_footprint_pinned():
    """Count AND bytes of one fixed solve config, pinned: the solver's
    mesh program must not grow a collective or a hidden word-plane
    without this test noticing — a sharper guard on the coalescing
    invariant than the op count alone."""
    from repro.core.listrank.config import ListRankConfig
    cfg = ListRankConfig(srs_rounds=1, local_contraction=False)
    fp = solve_footprint(256, mesh1(), cfg)
    assert fp["all_to_all"] == PINNED_SOLVE_FOOTPRINT, fp


# ------------------------------------------------------- mailbox kernel
def test_mailbox_pack_pallas_matches_ref():
    rng = np.random.default_rng(8)
    q, n_rows, w = 40, 24, 5
    cols = [jnp.asarray(rng.integers(-1000, 1000, q), jnp.int32)
            for _ in range(w)]
    # unique in-range slots plus some out-of-range (non-shipping rows)
    slots = rng.permutation(n_rows + 16)[:q].astype(np.int32)
    slots = jnp.asarray(slots)
    a = mp_ops.mailbox_pack(cols, slots, n_rows, use_pallas=True)
    b = mp_ops.mailbox_pack(cols, slots, n_rows, use_pallas=False)
    assert a.shape == (w, n_rows)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # oracle
    want = np.zeros((w, n_rows), np.int32)
    for i, s in enumerate(np.asarray(slots)):
        if s < n_rows:
            for j in range(w):
                want[j, s] = int(cols[j][i])
    np.testing.assert_array_equal(np.asarray(b), want)


# The multi-PE exchange smoke moved to the consolidated subprocess
# driver: tests/test_listrank_multi.py::test_subprocess_smoke[exchange].
