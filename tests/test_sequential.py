"""Oracle tests for the vectorized sequential ranker.

``rank_list_seq`` was rewritten from a per-terminal Python walk (plus a
second cycle-check walk — two O(n) interpreter loops) to vectorized
numpy pointer jumping. The original walk implementation is kept *here*
as the oracle-of-oracles (same pattern as the ``instances.py``
vectorization): outputs must match exactly on integer weights and to
float tolerance on float32 weights, and both error behaviors
(non-zero terminal weight, cycles) must be preserved.
"""
import numpy as np
import pytest

from repro.core.listrank import instances
from repro.core.listrank.sequential import rank_list_seq


def ref_rank_list_seq(succ, rank=None):
    """The pre-vectorization implementation: walk each list backwards
    from its terminal accumulating distance."""
    succ = np.asarray(succ)
    n = succ.shape[0]
    idx = np.arange(n, dtype=succ.dtype)
    if rank is None:
        rank = (succ != idx).astype(np.int64)
    rank = np.asarray(rank)
    if not np.all(rank[succ == idx] == 0):
        raise ValueError("terminal elements must carry weight 0")

    succ_out = np.empty_like(succ)
    rank_out = np.zeros(n, dtype=rank.dtype)
    nonterm = succ != idx
    pred = np.full(n, -1, dtype=np.int64)
    pred[succ[nonterm]] = idx[nonterm]
    terminals = idx[succ == idx]
    for t in terminals:
        succ_out[t] = t
        rank_out[t] = 0
        cur = pred[t]
        dist = rank_out[t]
        while cur != -1:
            dist = dist + rank[cur]
            succ_out[cur] = t
            rank_out[cur] = dist
            cur = pred[cur]
    visited = np.zeros(n, dtype=bool)
    visited[terminals] = True
    for t in terminals:
        cur = pred[t]
        while cur != -1:
            visited[cur] = True
            cur = pred[cur]
    if not visited.all():
        raise ValueError("input contains a cycle (not a set of lists)")
    return succ_out, rank_out


@pytest.mark.parametrize("n,gamma,num_lists,seed", [
    (1, 0.0, 1, 0), (2, 1.0, 1, 1), (17, 0.5, 1, 2), (64, 1.0, 1, 3),
    (128, 0.3, 5, 4), (257, 1.0, 9, 5),
])
def test_matches_walk_on_lists(n, gamma, num_lists, seed):
    succ, rank = instances.gen_list(n, gamma, seed=seed, num_lists=num_lists)
    s_ref, r_ref = ref_rank_list_seq(succ, rank)
    s, r = rank_list_seq(succ, rank)
    np.testing.assert_array_equal(s, s_ref)
    np.testing.assert_array_equal(r, r_ref)
    assert r.dtype == r_ref.dtype


@pytest.mark.parametrize("n,num_lists,seed", [
    (64, 3, 0), (200, 11, 1), (333, 1, 2),
])
def test_matches_walk_weighted(n, num_lists, seed):
    succ, rank = instances.gen_random_lists(n, num_lists=num_lists,
                                            seed=seed, weighted=True)
    s_ref, r_ref = ref_rank_list_seq(succ, rank)
    s, r = rank_list_seq(succ, rank)
    np.testing.assert_array_equal(s, s_ref)
    np.testing.assert_array_equal(r, r_ref)


def test_matches_walk_default_rank():
    succ, _ = instances.gen_list(100, gamma=1.0, seed=7, num_lists=4)
    s_ref, r_ref = ref_rank_list_seq(succ)
    s, r = rank_list_seq(succ)
    np.testing.assert_array_equal(s, s_ref)
    np.testing.assert_array_equal(r, r_ref)
    assert r.dtype == np.int64


def test_matches_walk_signed_weights():
    """±1 Euler-tour weights (negative links) rank identically."""
    succ, rank, _ = instances.gen_euler_tour(129, seed=3, weighted=True)
    s_ref, r_ref = ref_rank_list_seq(succ, rank)
    s, r = rank_list_seq(succ, rank)
    np.testing.assert_array_equal(s, s_ref)
    np.testing.assert_array_equal(r, r_ref)


def test_matches_walk_float_weights():
    rng = np.random.default_rng(0)
    succ, _ = instances.gen_random_lists(128, num_lists=4, seed=13)
    w = rng.uniform(0.0, 2.0, 128).astype(np.float32)
    w[succ == np.arange(128)] = 0.0
    s_ref, r_ref = ref_rank_list_seq(succ, w)
    s, r = rank_list_seq(succ, w)
    np.testing.assert_array_equal(s, s_ref)
    # accumulation order differs (backward walk vs pairwise jumping)
    np.testing.assert_allclose(r, r_ref, rtol=1e-5, atol=1e-5)
    assert r.dtype == np.float32


def test_empty_input():
    s, r = rank_list_seq(np.zeros(0, np.int32))
    assert s.shape == (0,) and r.shape == (0,)


def test_rejects_nonzero_terminal_weight():
    succ = np.array([1, 1], np.int32)
    rank = np.array([1, 5], np.int64)
    with pytest.raises(ValueError, match="terminal"):
        rank_list_seq(succ, rank)
    with pytest.raises(ValueError, match="terminal"):
        ref_rank_list_seq(succ, rank)


@pytest.mark.parametrize("succ", [
    [1, 0],                  # 2-cycle (collapses to a spurious fixed
                             # point under jumping — the regression case)
    [1, 2, 0],               # 3-cycle
    [1, 2, 0, 4, 4],         # cycle plus a healthy list
])
def test_rejects_cycles(succ):
    succ = np.asarray(succ, np.int32)
    rank = (succ != np.arange(len(succ))).astype(np.int64)
    with pytest.raises(ValueError, match="cycle"):
        rank_list_seq(succ, rank)
    with pytest.raises(ValueError, match="cycle"):
        ref_rank_list_seq(succ, rank)


@pytest.mark.parametrize("succ", [
    [2, 2, 2],               # two elements share a successor (a tree)
    [1, 2, 3, 1],            # rho: tail merging into a cycle
    [3, 3, 3, 3, 5, 5],      # three-way merge plus a healthy list
])
def test_rejects_merged_lists(succ):
    """In-degree >= 2 is not a set of lists; jumping would silently
    rank it, so the oracle must reject it like the walk version did."""
    succ = np.asarray(succ, np.int32)
    rank = (succ != np.arange(len(succ))).astype(np.int64)
    with pytest.raises(ValueError, match="not a set of lists"):
        rank_list_seq(succ, rank)
    with pytest.raises(ValueError, match="not a set of lists"):
        ref_rank_list_seq(succ, rank)
