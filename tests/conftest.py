import os
import sys

# tests run on the single real CPU device (the 512-device override is
# exclusively the dry-run's); multi-device list-ranking tests spawn
# subprocesses that set XLA_FLAGS before importing jax.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))  # _hypothesis_compat et al.

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")


import pytest  # noqa: E402


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    """XLA:CPU accumulates JIT-compiled executables across this large
    suite (hundreds of distinct programs incl. hypothesis variants);
    without eviction the CPU JIT eventually aborts. Dropping caches at
    module boundaries keeps the long single-process run healthy."""
    yield
    jax.clear_caches()
