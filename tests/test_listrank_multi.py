"""Multi-PE (8 virtual devices) list ranking: correctness across
indirection schemes + the paper's round/subproblem predictions.
Runs in a subprocess because the device count must be fixed before jax
initializes (the main test process keeps the single real device)."""
import pathlib
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_multi_device_matrix():
    script = pathlib.Path(__file__).parent / "_multi_device_matrix.py"
    proc = subprocess.run([sys.executable, str(script)],
                          capture_output=True, text=True, timeout=2400)
    print(proc.stdout)
    print(proc.stderr[-2000:] if proc.stderr else "")
    assert proc.returncode == 0, "multi-device matrix failed"


@pytest.mark.slow
def test_treealg_multi_device():
    script = pathlib.Path(__file__).parent / "_treealg_multi.py"
    proc = subprocess.run([sys.executable, str(script)],
                          capture_output=True, text=True, timeout=2400)
    print(proc.stdout)
    print(proc.stderr[-2000:] if proc.stderr else "")
    assert proc.returncode == 0, "multi-device treealg matrix failed"


@pytest.mark.slow
def test_graphalg_multi_device():
    script = pathlib.Path(__file__).parent / "_graphalg_multi.py"
    proc = subprocess.run([sys.executable, str(script)],
                          capture_output=True, text=True, timeout=2400)
    print(proc.stdout)
    print(proc.stderr[-2000:] if proc.stderr else "")
    assert proc.returncode == 0, "multi-device graphalg matrix failed"
