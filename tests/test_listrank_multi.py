"""Multi-PE (8 real virtual devices) smoke layer — subprocess because
the device count must be fixed before jax initializes.

This is the thin *device-path* tier: the behavioral cross-product
(families x p x wire x algorithm) moved in-process onto the simshard
backend (tests/test_simshard_matrix.py), and tests/golden/ pins
simshard == mesh byte-for-byte. What remains here per subsystem is what
only real devices can exercise: live ``all_to_all`` lowering, multi-hop
indirection across devices, the Pallas kernels, and on-mesh collective
counts. See TESTING.md for the full tier split.
"""
import pathlib
import subprocess
import sys

import pytest

SUITES = ("exchange", "listrank", "treealg", "graphalg",
          pytest.param("faultinject", marks=pytest.mark.faultinject),
          pytest.param("obs", marks=pytest.mark.obs))


@pytest.mark.slow
@pytest.mark.parametrize("suite", SUITES)
def test_subprocess_smoke(suite):
    script = pathlib.Path(__file__).parent / "_subprocess_smoke.py"
    proc = subprocess.run([sys.executable, str(script), suite],
                          capture_output=True, text=True, timeout=2400)
    print(proc.stdout)
    print(proc.stderr[-2000:] if proc.stderr else "")
    assert proc.returncode == 0, f"{suite} smoke failed"
