"""The consolidated 8-real-device subprocess smoke driver.

    python tests/_subprocess_smoke.py <suite>     # exchange | listrank
                                                  # | treealg | graphalg

One thin smoke layer per subsystem on a REAL (2, 4) virtual-device
mesh — the simshard in-process matrix (tests/test_simshard_matrix.py
et al.) now carries the behavioral cross-product, and the golden pins
(tests/golden/) prove simshard == mesh bit-for-bit, so these
subprocesses only need to keep the device path honest: real
``all_to_all`` lowering, multi-hop indirection on actual devices, the
Pallas kernels (which simshard rejects), and the jaxpr collective
counts on a live mesh. Replaces the former ``_exchange_multi.py`` /
``_multi_device_matrix.py`` / ``_treealg_multi.py`` /
``_graphalg_multi.py`` (see TESTING.md for the tier split).

Runs as a subprocess because the device count must be fixed before jax
initializes; exits nonzero on any failure.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.core.listrank import (IndirectionSpec, ListRankConfig,  # noqa
                                 instances, introspect, rank_list_seq,
                                 rank_list_with_stats)
from repro.core.listrank.exchange import (MeshPlan, compact_queue,  # noqa
                                          remote_gather, route)

AXES = ("row", "col")
P_ALL = P(AXES)
FAILURES = 0


def check(name, ok):
    global FAILURES
    print(("OK  " if ok else "FAIL") + " " + name)
    if not ok:
        FAILURES += 1


def _mesh():
    return compat.make_mesh((2, 4), AXES)


# --------------------------------------------------------------------------
# exchange: routing/gather primitives on real devices
# --------------------------------------------------------------------------

def suite_exchange():
    mesh = _mesh()
    p, q = 8, 32
    rng = np.random.default_rng(1)
    payload = {"ia": rng.integers(-50, 50, p * q).astype(np.int32),
               "fb": rng.normal(size=p * q).astype(np.float32)}
    dest = rng.integers(0, p, p * q).astype(np.int32)
    valid = rng.integers(0, 2, p * q).astype(bool)
    keys = sorted(payload.keys())
    specs = {"direct": (None, 1),
             "grid": (IndirectionSpec.grid(AXES), 2),
             "topo": (IndirectionSpec.topology(("col",), ("row",)), 2)}

    want = {}
    for i in np.flatnonzero(valid):
        want.setdefault(int(dest[i]), []).append(
            (int(payload["ia"][i]), int(payload["fb"][i].view(np.int32))))
    want = {k: sorted(v) for k, v in want.items()}

    def run_route(plan, caps):
        def fn(*leaves):
            pl = dict(zip(keys, leaves[:-2]))
            d, dv, lo, _ = route(plan, caps, pl, leaves[-2], leaves[-1])
            left = sum(jnp.sum(lv).astype(jnp.int32) for _, _, lv in lo)
            return d, dv, plan.psum(left)

        args = [jnp.asarray(payload[k]) for k in keys] + [
            jnp.asarray(dest), jnp.asarray(valid)]
        m = jax.jit(compat.shard_map(
            fn, mesh, in_specs=tuple(P_ALL for _ in args),
            out_specs=({k: P_ALL for k in keys}, P_ALL, P())))
        d, dv, left = m(*args)
        return {k: np.asarray(v) for k, v in d.items()}, \
            np.asarray(dv), int(left)

    for name, (ind, hops) in specs.items():
        caps = [q] if hops == 1 else [q, 8 * q]
        outs = {}
        ok = True
        for packed in (True, False):
            plan = MeshPlan.from_mesh(mesh, AXES, ind, wire_packing=packed)
            d, dv, left = run_route(plan, caps)
            ok &= left == 0  # both wire paths must fully deliver
            outs[packed] = (d, dv)
        d, dv = outs[True]
        r = dv.shape[0] // p
        for pe in range(p):
            got = sorted(
                (int(d["ia"][i]), int(d["fb"][i].view(np.int32)))
                for i in range(pe * r, (pe + 1) * r) if dv[i])
            ok &= got == want.get(pe, [])
        check(f"route oracle {name}", ok)
        (d1, v1), (d2, v2) = outs[True], outs[False]
        check(f"route packed==unpacked {name}",
              np.array_equal(v1, v2) and all(
                  np.array_equal(d1[k].view(np.int32),
                                 d2[k].view(np.int32)) for k in d1))

    # tiny capacities: leftover re-queue drains without loss (direct)
    plan = MeshPlan.from_mesh(mesh, AXES, None, wire_packing=True)

    def drain(*leaves):
        pl = dict(zip(keys, leaves[:-2]))
        cur_pl, cur_d, cur_v = pl, leaves[-2], leaves[-1]
        acc_ia, acc_dv = [], []
        for _ in range(24):
            dlv, dv, lo, _ = route(plan, [3], cur_pl, cur_d, cur_v)
            acc_ia.append(jnp.where(dv, dlv["ia"], -10 ** 6))
            acc_dv.append(dv)
            cur_pl, cur_d, cur_v, _ = compact_queue(lo, q)
        rest = plan.psum(jnp.sum(cur_v).astype(jnp.int32))
        return jnp.stack(acc_ia), jnp.stack(acc_dv), rest

    args = [jnp.asarray(payload[k]) for k in keys] + [
        jnp.asarray(dest), jnp.asarray(valid)]
    m = jax.jit(compat.shard_map(
        drain, mesh, in_specs=tuple(P_ALL for _ in args),
        out_specs=(P(None, AXES), P(None, AXES), P())))
    ia_r, dv_r, rest = m(*args)
    ia_r, dv_r = np.asarray(ia_r), np.asarray(dv_r)
    check("overflow drain",
          int(rest) == 0 and int(dv_r.sum()) == int(valid.sum())
          and sorted(ia_r[dv_r]) == sorted(payload["ia"][valid]))

    # remote_gather over 2-hop topo (src reconstruction), dedup on
    n = p * q
    targets = rng.integers(0, n, n).astype(np.int32)
    gvalid = rng.integers(0, 2, n).astype(bool)
    plan = MeshPlan.from_mesh(mesh, AXES,
                              IndirectionSpec.topology(("col",), ("row",)))

    def gather(t, v):
        def lookup(g, gv):
            return {"val": g * 3 + 7}
        out, answered, _ = remote_gather(
            plan, t, v, lambda g: g // q, lookup,
            req_cap=[n] * 2, resp_cap=[n] * 2, dedup=True)
        return out, answered

    m = jax.jit(compat.shard_map(
        gather, mesh, in_specs=(P_ALL, P_ALL),
        out_specs=({"val": P_ALL}, P_ALL)))
    out, answered = m(jnp.asarray(targets), jnp.asarray(gvalid))
    check("gather topo dedup",
          np.array_equal(np.asarray(answered), gvalid)
          and np.array_equal(np.asarray(out["val"])[gvalid],
                             targets[gvalid] * 3 + 7))

    # collective counts on the live mesh (the coalescing acceptance pin)
    for name, (ind, hops) in specs.items():
        for packed, per_hop in ((True, 1), (False, 4)):
            plan = MeshPlan.from_mesh(mesh, AXES, ind, wire_packing=packed)

            def fn(*leaves, plan=plan, hops=hops):
                pl = dict(zip(keys, leaves[:-2]))
                d, dv, _, _ = route(plan, [q] * hops, pl, leaves[-2],
                                    leaves[-1])
                return d, dv

            m = compat.shard_map(
                fn, mesh, in_specs=tuple(P_ALL for _ in args),
                out_specs=({k: P_ALL for k in keys}, P_ALL))
            counts = introspect.collective_counts(m, *args)
            check(f"collectives {name} packed={packed}",
                  counts.get("all_to_all", 0) == per_hop * hops)


# --------------------------------------------------------------------------
# listrank: solver end to end on real devices (incl. the Pallas paths
# simshard rejects)
# --------------------------------------------------------------------------

def suite_listrank():
    mesh = _mesh()
    base = ListRankConfig(srs_rounds=1, local_contraction=False)
    grid = IndirectionSpec.grid(AXES)
    n = 1024
    sg1, rg1 = instances.gen_list(n, gamma=1.0, seed=1)
    sml, rml = instances.gen_random_lists(n, num_lists=11, seed=4,
                                          weighted=True)
    se, re_, _ = instances.gen_euler_tour(n // 2 + 1, seed=6, locality=True)
    se, re_ = instances.pad_to_multiple(se, re_, 8)

    topo = IndirectionSpec.topology(("col",), ("row",))
    cases = [
        ("srs2 contract", sg1, rg1,
         base.with_(srs_rounds=2, local_contraction=True), None),
        ("srs1 grid", sg1, rg1, base, grid),
        ("srs1 topo", sg1, rg1, base, topo),
        ("reversal", sg1, rg1, base.with_(avoid_reversal=False), None),
        ("doubling grid", sg1, rg1, base.with_(algorithm="doubling"), grid),
        ("weighted multilist", sml, rml,
         base.with_(local_contraction=True), None),
        ("euler rgg2d contract", se, re_,
         base.with_(local_contraction=True), None),
        ("pallas contract", sg1, rg1,
         base.with_(local_contraction=True, use_pallas=True), None),
        ("pallas mailbox pack", sg1, rg1, base.with_(use_pallas_pack=True),
         None),
    ]
    for name, succ, rank, cfg, ind in cases:
        s_ref, r_ref = rank_list_seq(succ, rank)
        s, r, stats = rank_list_with_stats(succ, rank, mesh, cfg=cfg,
                                           indirection=ind)
        check(f"listrank {name}",
              np.array_equal(np.asarray(s), s_ref)
              and np.array_equal(np.asarray(r), r_ref))

    # paper-theory smoke (§2.2): rounds ~ n/r + 1; |sub| ~ r ln(n/r)
    import math
    cfg = base.with_(ruler_fraction=1 / 32)
    _, _, stats = rank_list_with_stats(sg1, rg1, mesh, cfg=cfg)
    rounds = stats["rounds"] // 8
    r_tot = 8 * max(4, int(n / 8 / 32))
    check("round bound", rounds <= 4 * (n / r_tot + 1))
    check("sub size",
          stats["sub_size"] <= 3 * r_tot * math.log(n / r_tot) + 64)


# --------------------------------------------------------------------------
# treealg: device tour + stats + batched front door
# --------------------------------------------------------------------------

def suite_treealg():
    from _tree_oracles import dfs_stats
    from repro.core import treealg
    mesh = _mesh()
    cfg = ListRankConfig(srs_rounds=1, local_contraction=True)

    n = 501
    parent = instances.gen_tree_parents(n, seed=9, locality=False,
                                        num_trees=7)
    succ, w, _ = treealg.build_tour(parent, mesh, cfg=cfg)
    got = np.asarray(jax.device_get(succ))[:2 * n]
    check("tour forest",
          np.array_equal(got, treealg.oracle_tour(n, parent).astype(
              np.int32)))

    parent = instances.gen_tree_parents(409, seed=8, locality=True)
    st = treealg.tree_stats(parent, mesh, cfg=cfg)
    d, s, pre, post = dfs_stats(parent)
    check("stats rgg2d", np.array_equal(st.depth, d)
          and np.array_equal(st.subtree_size, s)
          and np.array_equal(st.preorder, pre)
          and np.array_equal(st.postorder, post))

    parent = instances.gen_tree_parents(300, 17)
    newp = treealg.root_tree(parent, 271, mesh, cfg=cfg)
    e_old = {frozenset((c, int(parent[c]))) for c in range(300)
             if parent[c] != c}
    e_new = {frozenset((c, int(newp[c]))) for c in range(300)
             if newp[c] != c}
    d2, _, _, _ = dfs_stats(newp)
    check("root_tree", e_old == e_new and newp[271] == 271
          and d2[271] == 0)

    batch = [instances.gen_list(128, gamma=1.0, seed=s) for s in range(2)]
    batch.append(instances.gen_random_lists(160, num_lists=6, seed=5,
                                            weighted=True))
    results, stats = treealg.rank_lists_with_stats(batch, mesh, cfg=cfg)
    ok = stats["attempts"] == 1
    for (s_in, r_in), (s_out, r_out) in zip(batch, results):
        s_ref, r_ref = rank_list_seq(s_in, r_in)
        ok = ok and np.array_equal(s_out, s_ref) \
            and np.array_equal(r_out, r_ref)
    check("rank_lists batch", ok)

    parents = [instances.gen_tree_parents(nn, seed=nn,
                                          locality=bool(nn % 2))
               for nn in (9, 120)]
    out = treealg.solve_forest(parents, mesh, cfg=cfg)
    ok = True
    for q, st in zip(parents, out):
        d, s, pre, post = dfs_stats(q)
        ok = ok and np.array_equal(st.depth, d) \
            and np.array_equal(st.subtree_size, s) \
            and np.array_equal(st.preorder, pre) \
            and np.array_equal(st.postorder, post)
    check("solve_forest", ok)


# --------------------------------------------------------------------------
# graphalg: cc / forest / stats on real devices
# --------------------------------------------------------------------------

def suite_graphalg():
    from _graph_oracles import check_spanning_forest, union_find_labels
    from _tree_oracles import dfs_stats
    from repro.core import graphalg
    mesh = _mesh()
    cfg = ListRankConfig(srs_rounds=1, local_contraction=True)

    for name, n, e, kw in [
            ("gnm", 240, 400, dict(locality=False)),
            ("rgg2d multi", 200, 260, dict(locality=True,
                                           num_components=4)),
            ("empty", 16, None, np.zeros((0, 2), np.int64))]:
        edges = (instances.gen_graph_edges(n, e, seed=len(name), **kw)
                 if e is not None else kw)
        ref = union_find_labels(n, edges)
        labels, st = graphalg.connected_components(edges, n, mesh, cfg=cfg)
        check(f"cc {name}", np.array_equal(labels, ref)
              and st["cc_unconverged"] == 0)
        parent, lab2, st2 = graphalg.spanning_forest(edges, n, mesh,
                                                     cfg=cfg)
        check(f"forest {name}",
              check_spanning_forest(n, edges, parent, lab2) == [] and
              st2["forest_edges"] == n - np.unique(ref).size)

    edges = instances.gen_graph_edges(220, 360, seed=8, locality=False)
    gs = graphalg.graph_stats(edges, 220, mesh, cfg=cfg)
    depth, size, pre, post = dfs_stats(gs.parent)
    check("graph_stats gnm",
          check_spanning_forest(220, edges, gs.parent, gs.components) == []
          and np.array_equal(gs.depth, depth)
          and np.array_equal(gs.subtree_size, size)
          and np.array_equal(gs.preorder, pre)
          and np.array_equal(gs.postorder, post))


# --------------------------------------------------------------------------
# faultinject: recovery + elastic checkpoint restore on real devices.
# The cross-backend halves (mesh checkpoint -> simshard resume and the
# reverse) can only run where a real mesh exists, so they live here; the
# rest of the recovery matrix is in-process (tests/test_faultinject.py).
# --------------------------------------------------------------------------

def suite_faultinject():
    import tempfile
    from _simshard_cases import (AXES as G_AXES, SHAPE as G_SHAPE,
                                 case_record, golden_cases, load_golden)
    from repro.core.listrank import FaultSpec, sim_mesh
    from repro.runtime.fault_tolerance import (Preempted, SolveSupervisor,
                                               SolveSupervisorConfig)

    name = "list-g1-s1"
    s, r, cfg = next((s, r, c) for nm, s, r, c in golden_cases()
                     if nm == name)
    gold = load_golden(name)
    dev_mesh = compat.make_mesh(G_SHAPE, G_AXES)
    backends = {"mesh": lambda: dev_mesh,
                "sim": lambda: sim_mesh(G_SHAPE, G_AXES)}

    def sup(d):
        return SolveSupervisor(SolveSupervisorConfig(ckpt_dir=d))

    # elastic restore: preempt on one backend, resume on the other; the
    # finished record must equal the committed golden exactly.
    for src, dst in (("mesh", "sim"), ("sim", "mesh")):
        with tempfile.TemporaryDirectory() as d:
            preempted = False
            try:
                rank_list_with_stats(
                    s, r, backends[src](), cfg=cfg, supervisor=sup(d),
                    inject=FaultSpec("preempt", stage="descend", level=0))
            except Preempted:
                preempted = True
            check(f"preempt on {src}", preempted)
            sf, rf, stats = rank_list_with_stats(
                s, r, backends[dst](), cfg=cfg, supervisor=sup(d))
            check(f"elastic restore {src}->{dst}",
                  case_record(sf, rf, stats) == gold
                  and stats["recovery"]["resumed_from"] == 2
                  and stats["stage_log"] == ("base@1", "ascend@0", "post"))

    # crash recovery on the real mesh: restore from the level boundary,
    # never re-executing the completed levels.
    with tempfile.TemporaryDirectory() as d:
        sf, rf, stats = rank_list_with_stats(
            s, r, dev_mesh, cfg=cfg, supervisor=sup(d),
            inject=FaultSpec("pe_loss", stage="base"))
        check("mesh pe_loss recovery",
              case_record(sf, rf, stats) == gold
              and stats["recovery"]["restarts"] == 1
              and stats["recovery"]["resumed_from"] == 2
              and stats["stage_log"].count("descend@0") == 1)

    # injected overflow: escalate-and-resume reproduces the golden bytes
    sf, rf, stats = rank_list_with_stats(
        s, r, dev_mesh, cfg=cfg,
        inject=FaultSpec("overflow", stage="descend", level=0,
                         family="chase"))
    rec = case_record(sf, rf, stats)
    check("mesh injected overflow",
          rec["succ_sha256"] == gold["succ_sha256"]
          and rec["rank_sha256"] == gold["rank_sha256"]
          and stats["attempts"] == 2)


def suite_obs():
    """Flight recorder on the REAL mesh backend: a traced full solve
    must cover every scheduled stage with measured + predicted times,
    reproduce the committed golden bytes exactly (no-perturbation), and
    export a loadable Chrome trace. Writes the trace artifact to
    $OBS_TRACE_OUT when set (the CI simshard-matrix job uploads it)."""
    import json
    from _simshard_cases import (AXES as G_AXES, SHAPE as G_SHAPE,
                                 case_record, golden_cases, load_golden)
    from repro import obs
    from repro.core.listrank import resume as resume_lib

    name = "list-g1-s1"
    s, r, cfg = next((s, r, c) for nm, s, r, c in golden_cases()
                     if nm == name)
    mesh = compat.make_mesh(G_SHAPE, G_AXES)
    tr = obs.Tracer(meta={"name": f"smoke-obs/{name}", "backend": "mesh"})
    sf, rf, stats = rank_list_with_stats(s, r, mesh, cfg=cfg, tracer=tr)
    check("mesh golden bytes identical with tracing on",
          case_record(sf, rf, stats) == load_golden(name))

    labels = [st.label for st in resume_lib.schedule_for(
        cfg.with_(algorithm="srs"))]
    stage_spans = list(tr.find(cat="stage"))
    check("mesh trace covers every scheduled stage once",
          [sp.name for sp in stage_spans] == labels)
    (solve,) = tr.find(cat="solve")
    check("mesh solve span", solve.args["backend"] == "mesh"
          and solve.args["outcome"] == "ok")
    rows = obs.residual_rows(tr)
    print(obs.format_residual_table(rows, title=f"== {name} (mesh)"))
    check("every stage has measured + predicted time",
          {row["stage"] for row in rows} == set(labels)
          and all(row["measured_s"] >= 0 for row in rows))

    out = os.environ.get("OBS_TRACE_OUT", "")
    path = out or os.path.join(os.path.dirname(__file__), "..",
                               "benchmarks", "results",
                               "mesh_solve_trace.json")
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    obs.write_chrome_trace(tr, path)
    doc = json.loads(open(path).read())
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    check("chrome trace round-trips with monotone timestamps",
          len(xs) == len(tr.spans)
          and [e["ts"] for e in xs] == sorted(e["ts"] for e in xs))
    print(f"wrote {path}")


SUITES = {"exchange": suite_exchange, "listrank": suite_listrank,
          "treealg": suite_treealg, "graphalg": suite_graphalg,
          "faultinject": suite_faultinject, "obs": suite_obs}


def main():
    if len(sys.argv) != 2 or sys.argv[1] not in SUITES:
        print(f"usage: {sys.argv[0]} {{{'|'.join(SUITES)}}}")
        sys.exit(2)
    SUITES[sys.argv[1]]()
    print("failures:", FAILURES)
    sys.exit(1 if FAILURES else 0)


if __name__ == "__main__":
    main()
