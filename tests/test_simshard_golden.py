"""Golden bit-identity pins: simshard == the 8-device mesh, byte for
byte.

The committed ``tests/golden/*.json`` records were produced by the
REAL-mesh subprocess run (``tests/_golden_multi.py --write``). The fast
tests here re-run every case on the simshard virtual-PE backend
in-process and assert the solve output hashes, attempt count,
per-attempt capacity-escalation path, and every solver counter are
identical — the emulation is the mesh program, not an approximation of
it. The slow test re-runs the mesh subprocess and revalidates the
committed records themselves.
"""
import json
import pathlib
import subprocess
import sys

import pytest

from repro.core.listrank import rank_list_with_stats, sim_mesh

import _simshard_cases as cases_lib

_CASES = cases_lib.golden_cases()


@pytest.mark.parametrize("case", _CASES, ids=[c[0] for c in _CASES])
def test_simshard_matches_mesh_golden(case):
    name, succ, rank, cfg = case
    golden = cases_lib.load_golden(name)
    mesh = sim_mesh(cases_lib.SHAPE, cases_lib.AXES)
    s, r, stats = rank_list_with_stats(succ, rank, mesh, cfg=cfg)
    rec = cases_lib.case_record(s, r, stats)
    assert rec == golden, (
        f"simshard diverged from the mesh golden for {name}: "
        f"{ {k: (rec[k], golden[k]) for k in rec if rec[k] != golden[k]} }")


def test_every_golden_has_a_case():
    """No stale committed goldens (a renamed case must retire its file)."""
    names = {c[0] for c in _CASES}
    on_disk = {p.stem for p in cases_lib.GOLDEN_DIR.glob("*.json")}
    assert on_disk == names


@pytest.mark.slow
def test_mesh_golden_regen():
    """The committed goldens ARE the current mesh output (subprocess
    8-device re-run)."""
    script = pathlib.Path(__file__).parent / "_golden_multi.py"
    proc = subprocess.run([sys.executable, str(script)],
                          capture_output=True, text=True, timeout=2400)
    print(proc.stderr[-2000:] if proc.returncode else "")
    assert proc.returncode == 0, "golden generator failed"
    seen = set()
    for line in proc.stdout.splitlines():
        if not line.startswith("GOLDEN "):
            continue
        rec = json.loads(line[len("GOLDEN "):])
        name = rec.pop("name")
        seen.add(name)
        assert rec == cases_lib.load_golden(name), f"mesh drifted: {name}"
    assert seen == {c[0] for c in _CASES}
