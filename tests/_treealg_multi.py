"""Subprocess body for multi-PE treealg tests (8 virtual devices).

Run as: python tests/_treealg_multi.py — exits nonzero on any mismatch
against the DFS / instances.py oracles. Must set XLA_FLAGS before jax.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import jax  # noqa: E402

from _tree_oracles import dfs_stats  # noqa: E402
from repro import compat  # noqa: E402
from repro.core import treealg  # noqa: E402
from repro.core.listrank import (ListRankConfig, instances,  # noqa: E402
                                 rank_list_seq)
from repro.core.listrank.instances import gen_tree_parents  # noqa: E402


def main():
    mesh = compat.make_mesh((2, 4), ("row", "col"))
    cfg = ListRankConfig(srs_rounds=1, local_contraction=True)
    failures = 0

    def check(name, ok):
        nonlocal failures
        print(("OK  " if ok else "FAIL") + f" {name}")
        failures += 0 if ok else 1

    # device tour construction vs host oracle across families (blocks
    # span PEs, children cross PE boundaries)
    for name, kw in [("tour gnm", dict(locality=False)),
                     ("tour rgg2d", dict(locality=True)),
                     ("tour forest", dict(locality=False, num_trees=7))]:
        n = 501
        parent = gen_tree_parents(n, seed=len(name), **kw)
        succ, w, _ = treealg.build_tour(parent, mesh, cfg=cfg)
        got = np.asarray(jax.device_get(succ))[:2 * n]
        check(name, np.array_equal(got,
                                   treealg.oracle_tour(n, parent).astype(
                                       np.int32)))

    # tree statistics vs DFS oracle
    for name, kw in [("stats gnm", dict(locality=False)),
                     ("stats rgg2d", dict(locality=True)),
                     ("stats forest", dict(locality=True, num_trees=5))]:
        parent = gen_tree_parents(409, seed=3 + len(name), **kw)
        st = treealg.tree_stats(parent, mesh, cfg=cfg)
        d, s, pre, post = dfs_stats(parent)
        check(name, np.array_equal(st.depth, d)
              and np.array_equal(st.subtree_size, s)
              and np.array_equal(st.preorder, pre)
              and np.array_equal(st.postorder, post))

    # re-rooting
    parent = gen_tree_parents(300, 17)
    newp = treealg.root_tree(parent, 271, mesh, cfg=cfg)
    e_old = {frozenset((c, int(parent[c]))) for c in range(300)
             if parent[c] != c}
    e_new = {frozenset((c, int(newp[c]))) for c in range(300)
             if newp[c] != c}
    d2, _, _, _ = dfs_stats(newp)
    check("root_tree", e_old == e_new and newp[271] == 271
          and d2[271] == 0)

    # batched front door: one invocation, oracle-correct per instance
    batch = [instances.gen_list(128, gamma=1.0, seed=s) for s in range(3)]
    batch.append(instances.gen_random_lists(160, num_lists=6, seed=5,
                                            weighted=True))
    se, re_, _ = instances.gen_euler_tour(65, seed=6, weighted=True,
                                          num_trees=2)
    batch.append((se, re_))
    results, stats = treealg.rank_lists_with_stats(batch, mesh, cfg=cfg)
    ok = stats["attempts"] == 1
    for (s_in, r_in), (s_out, r_out) in zip(batch, results):
        s_ref, r_ref = rank_list_seq(s_in, r_in)
        ok = ok and np.array_equal(s_out, s_ref) \
            and np.array_equal(r_out, r_ref)
    check("rank_lists batch of 5", ok)

    # solve_forest: B trees, one tour build + one batched solve
    parents = [gen_tree_parents(n, seed=n, locality=bool(n % 2))
               for n in (9, 47, 120, 200)]
    out = treealg.solve_forest(parents, mesh, cfg=cfg)
    ok = True
    for q, st in zip(parents, out):
        d, s, pre, post = dfs_stats(q)
        ok = ok and np.array_equal(st.depth, d) \
            and np.array_equal(st.subtree_size, s) \
            and np.array_equal(st.preorder, pre) \
            and np.array_equal(st.postorder, post)
    check("solve_forest", ok)

    print("failures:", failures)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
