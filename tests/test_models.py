"""Per-arch smoke tests (assignment requirement): instantiate the
reduced config of each family, run one forward + one train step on CPU,
assert output shapes and no NaNs; plus decode-vs-forward consistency."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.optim import adamw
from repro.train import steps as train_steps

RNG = np.random.default_rng(0)


def _batch(cfg, b=2, l=32, labels=True):
    out = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, l)),
                                 jnp.int32)}
    total = l
    if cfg.family == "encdec":
        out["enc_embeds"] = jnp.asarray(
            RNG.normal(size=(b, l, cfg.prefix_embed_dim)), jnp.float32)
    elif cfg.prefix_embed_dim:
        npatch = 8
        out["prefix_embeds"] = jnp.asarray(
            RNG.normal(size=(b, npatch, cfg.prefix_embed_dim)), jnp.float32)
        total = l + npatch
    if labels:
        out["labels"] = jnp.asarray(
            RNG.integers(0, cfg.vocab_size, (b, total)), jnp.int32)
    return out, total


@pytest.mark.parametrize("arch", configs.list_archs())
def test_arch_forward_and_train_step(arch):
    cfg = configs.get_config(arch, smoke=True)
    params = M.init(jax.random.PRNGKey(0), cfg)
    batch, total = _batch(cfg)
    logits, aux = jax.jit(lambda p, b: M.forward(p, b, cfg))(params, batch)
    assert logits.shape == (2, total, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"

    tcfg = train_steps.TrainConfig(optimizer=adamw.AdamWConfig(lr=1e-3),
                                   warmup_steps=1, total_steps=10)
    opt = adamw.init(params, tcfg.optimizer)
    step = jax.jit(functools.partial(train_steps.train_step, cfg=cfg,
                                     tcfg=tcfg))
    params2, opt2, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"])), "NaN loss"
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # parameters actually moved
    moved = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", ["gemma2-2b", "hymba-1.5b", "mamba2-130m",
                                  "granite-moe-1b-a400m"])
def test_arch_decode_consistency(arch):
    """Incremental decode must reproduce the full forward pass."""
    cfg = configs.get_config(arch, smoke=True)
    if cfg.moe:
        cfg = cfg.with_(capacity_factor=8.0)  # no drops for determinism
    params = M.init(jax.random.PRNGKey(1), cfg)
    b, seq = 2, 16
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, seq)), jnp.int32)
    full, _ = M.forward(params, {"tokens": toks}, cfg)
    half = seq // 2
    cache = M.init_cache(cfg, b, seq)
    lg, cache = M.prefill(params, {"tokens": toks[:, :half]}, cfg, cache)
    np.testing.assert_allclose(np.asarray(lg[:, -1]),
                               np.asarray(full[:, half - 1]), atol=2e-3)
    for t in range(half, seq):
        lg, cache = M.decode_step(params, toks[:, t:t + 1], t, cfg, cache)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full[:, t]), atol=2e-3)


def test_grad_accumulation_equivalence():
    """microbatches=2 must match a single big batch (mean loss grads)."""
    cfg = configs.get_config("tinyllama-1.1b", smoke=True)
    params = M.init(jax.random.PRNGKey(0), cfg)
    tcfg1 = train_steps.TrainConfig(optimizer=adamw.AdamWConfig(lr=1e-3),
                                    warmup_steps=1, total_steps=10,
                                    microbatches=1)
    tcfg2 = train_steps.TrainConfig(optimizer=adamw.AdamWConfig(lr=1e-3),
                                    warmup_steps=1, total_steps=10,
                                    microbatches=2)
    batch, _ = _batch(cfg, b=4, l=16)
    opt = adamw.init(params, tcfg1.optimizer)
    p1, _, m1 = train_steps.train_step(params, opt, batch, cfg, tcfg1)
    p2, _, m2 = train_steps.train_step(params, opt, batch, cfg, tcfg2)
    # losses are per-token means over different denominators; compare
    # the resulting parameters loosely (same direction, similar size)
    d1 = jnp.concatenate([(a - b).ravel() for a, b in
                          zip(jax.tree.leaves(p1), jax.tree.leaves(params))])
    d2 = jnp.concatenate([(a - b).ravel() for a, b in
                          zip(jax.tree.leaves(p2), jax.tree.leaves(params))])
    cos = jnp.vdot(d1, d2) / (jnp.linalg.norm(d1) * jnp.linalg.norm(d2))
    assert float(cos) > 0.9


def test_use_kernels_matches_ref_path():
    cfg = configs.get_config("gemma2-2b", smoke=True)
    params = M.init(jax.random.PRNGKey(0), cfg)
    batch, _ = _batch(cfg, labels=False)
    l1, _ = M.forward(params, batch, cfg.with_(use_kernels=False))
    l2, _ = M.forward(params, batch, cfg.with_(use_kernels=True))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=2e-3,
                               rtol=1e-3)
