"""Tests for the §2.6 parameter-tuning subsystem (tuner.py) and its
wiring through build_specs / the retry driver.

Covers the ISSUE acceptance criteria: ruler_fraction=None demonstrably
routes through analysis.r_star, targeted retries rescale only the
offending capacity family (simulated per fatal stat, plus the forced
sub_overflow end-to-end check), auto-PD below the efficiency threshold,
and the build_specs consistency fixes (log p term in max_rounds,
r_target <= r_static by construction).
"""
import math

import numpy as np
import pytest

from repro import compat
from repro.core.listrank import (IndirectionSpec, ListRankConfig, analysis,
                                 instances, rank_list_seq,
                                 rank_list_with_stats, tuner)
from repro.core.listrank import api
from repro.core.listrank.exchange import MeshPlan


def mesh1():
    return compat.make_mesh((1,), ("pe",))


def plan_of(p=16, axes=2):
    if axes == 2:
        side = int(math.isqrt(p))
        return MeshPlan(pe_axes=("row", "col"), axis_sizes=(side, p // side),
                        indirection=IndirectionSpec.grid(("row", "col")))
    return MeshPlan(pe_axes=("pe",), axis_sizes=(p,),
                    indirection=IndirectionSpec.direct(("pe",)))


#: a machine whose startup cost dominates — huge efficiency threshold.
ALPHA_HEAVY = analysis.MachineModel(alpha=1.0, beta=1e-12, name="alpha-heavy")
#: effectively free startups — threshold ~ 0, SRS always efficient.
BETA_HEAVY = analysis.MachineModel(alpha=1e-12, beta=1.0, name="beta-heavy")


# --------------------------------------------------------------------------
# targeted capacity retries
# --------------------------------------------------------------------------

@pytest.mark.parametrize("stat,family", [
    ("dropped", "chase"),
    ("sub_overflow", "sub"),
    ("undelivered", "gather"),
])
def test_escalate_rescales_only_the_offending_family(stat, family):
    scales = tuner.escalate(tuner.CapacityScales(), {stat: 3})
    for f in ("chase", "sub", "gather"):
        assert getattr(scales, f) == (2.0 if f == family else 1.0), (stat, f)


def test_escalate_store_miss_and_unknown_rescale_globally():
    for stats in ({"store_miss": 1}, {}):
        scales = tuner.escalate(tuner.CapacityScales(), stats)
        assert (scales.chase, scales.sub, scales.gather) == (2.0, 2.0, 2.0)


def test_escalate_compounds_geometrically():
    s = tuner.CapacityScales()
    s = tuner.escalate(s, {"sub_overflow": 1})
    s = tuner.escalate(s, {"sub_overflow": 1, "dropped": 2})
    assert (s.chase, s.sub, s.gather) == (2.0, 4.0, 1.0)


def test_escalate_widens_when_targeting_proved_insufficient():
    """`undelivered` is not capacity-exclusive (chase coverage failures
    report it too): once the implicated family has already been
    rescaled and the stat persists, the retry must widen globally
    instead of re-doubling the wrong capacity forever."""
    s = tuner.escalate(tuner.CapacityScales(), {"undelivered": 5})
    assert (s.chase, s.sub, s.gather) == (1.0, 1.0, 2.0)
    s = tuner.escalate(s, {"undelivered": 5})  # same failure again
    assert s.chase > 1.0 and s.sub > 1.0 and s.gather > 2.0


def test_escalate_exclusive_stats_stay_targeted_forever():
    """Capacity-exclusive stats (dropped, sub_overflow) re-double only
    their own family no matter how often they fire — the widening
    ladder applies exclusively to the ambiguous stats."""
    s = tuner.CapacityScales()
    for _ in range(3):
        s = tuner.escalate(s, {"sub_overflow": 1})
    assert (s.chase, s.sub, s.gather) == (1.0, 8.0, 1.0)
    for _ in range(2):
        s = tuner.escalate(s, {"dropped": 1})
    assert (s.chase, s.sub, s.gather) == (4.0, 8.0, 1.0)


def test_sub_overflow_rescale_leaves_mail_and_queue_caps_unchanged():
    """The ISSUE acceptance check, at the build_specs level: a
    sub_overflow retry must change only the sub-store capacities."""
    cfg = ListRankConfig(srs_rounds=2)
    plan = plan_of()
    base = api.build_specs(cfg, plan, 1 << 12, 1 << 16, 4)
    esc = api.build_specs(cfg, plan, 1 << 12, 1 << 16, 4,
                          tuner.escalate(tuner.CapacityScales(),
                                         {"sub_overflow": 7}))
    assert esc[0].mail_caps == base[0].mail_caps
    assert esc[0].queue_cap == base[0].queue_cap
    assert esc[0].gather_req_cap == base[0].gather_req_cap
    assert esc[0].cap_sub > base[0].cap_sub


def test_dropped_rescale_leaves_gather_and_sub_caps_unchanged():
    cfg = ListRankConfig(srs_rounds=1)
    plan = plan_of()
    base = api.build_specs(cfg, plan, 1 << 12, 1 << 16, 4)
    esc = api.build_specs(cfg, plan, 1 << 12, 1 << 16, 4,
                          tuner.escalate(tuner.CapacityScales(),
                                         {"dropped": 1}))
    assert esc[0].cap_sub == base[0].cap_sub
    assert esc[0].gather_req_cap == base[0].gather_req_cap
    assert esc[0].mail_caps > base[0].mail_caps or \
        esc[0].queue_cap > base[0].queue_cap


def test_forced_sub_overflow_retry_end_to_end(monkeypatch):
    """Force a sub_overflow on attempt 1 (tiny sub_capacity_slack) and
    assert the retry that fixes it kept the chase/gather capacities of
    the failing attempt whenever only sub_overflow fired."""
    recorded = []
    orig = api.build_specs

    def spy(cfg, plan, m, n, term_bound, scales=tuner.CapacityScales(),
            estimate=None):
        specs = orig(cfg, plan, m, n, term_bound, scales, estimate)
        sc = tuner.normalize_level_scales(scales, cfg.srs_rounds + 1)
        # the staged driver rebuilds specs once per executed stage;
        # record one entry per *distinct* scale vector (= per attempt).
        if not recorded or recorded[-1][0] != sc:
            recorded.append((sc, specs))
        return specs

    monkeypatch.setattr(api, "build_specs", spy)
    succ, rank = instances.gen_list(256, gamma=1.0, seed=2)
    cfg = ListRankConfig(srs_rounds=1, local_contraction=False,
                         sub_capacity_slack=0.05)
    s_ref, r_ref = rank_list_seq(succ, rank)
    s, r, stats = rank_list_with_stats(succ, rank, mesh1(), cfg=cfg,
                                       max_retries=8)
    np.testing.assert_array_equal(np.asarray(s), s_ref)
    np.testing.assert_array_equal(np.asarray(r), r_ref)
    assert stats["attempts"] >= 2, "expected at least one forced retry"
    (first_scales, first_specs), (second_scales, second_specs) = recorded[:2]
    first_scales, second_scales = first_scales[0], second_scales[0]
    assert (first_scales.chase, first_scales.sub) == (1.0, 1.0)
    # the sub family was escalated, the chase family untouched
    assert second_scales.sub > 1.0
    assert second_scales.chase == 1.0
    assert second_specs[0].mail_caps == first_specs[0].mail_caps
    assert second_specs[0].queue_cap == first_specs[0].queue_cap
    assert second_specs[0].cap_sub > first_specs[0].cap_sub


# --------------------------------------------------------------------------
# ruler_fraction=None -> analysis.r_star
# --------------------------------------------------------------------------

def test_none_fraction_invokes_r_star(monkeypatch):
    calls = []
    orig = analysis.r_star

    def spy(n, p, d, m):
        calls.append((n, p, d))
        return orig(n, p, d, m)

    monkeypatch.setattr(analysis, "r_star", spy)
    cfg = ListRankConfig(ruler_fraction=None, srs_rounds=2)
    levels = tuner.level_plan(cfg, p=16, d=2, n=1 << 20)
    assert len(calls) == 2, "one r* derivation per level"
    assert calls[0][0] == 1 << 20
    # level 1 runs on the *expected* shrunken sub-instance
    assert calls[1][0] == levels[1].n_expected < (1 << 20)
    # fixed fraction must NOT consult the cost model
    calls.clear()
    tuner.level_plan(ListRankConfig(srs_rounds=2), p=16, d=2, n=1 << 20)
    assert calls == []


def test_none_fraction_differs_from_legacy_fallback():
    """The old silent 1/32 fallback is gone: with None the derived
    fraction is the cost model's, not 1/32."""
    cfg = ListRankConfig(ruler_fraction=None)
    levels = tuner.level_plan(cfg, p=16, d=2, n=1 << 20)
    assert levels[0].frac != pytest.approx(1.0 / 32.0)
    assert levels[0].r_total == min(
        max(analysis.r_star(1 << 20, 16, 2, cfg.machine),
            cfg.min_rulers_per_pe * 16),
        int(math.ceil(tuner.RULER_FRAC_CAP * (1 << 20))))


def test_build_specs_and_solver_share_one_derivation():
    """r_target can never exceed r_static: both come from the same
    LevelSpec.ruler_frac (spec carries the fraction the caps were sized
    for)."""
    for frac in (None, 1.0 / 32.0, 1.0 / 8.0):
        cfg = ListRankConfig(ruler_fraction=frac, srs_rounds=2)
        plan = plan_of()
        specs = api.build_specs(cfg, plan, 1 << 12, 1 << 16, 4)
        levels = tuner.level_plan(cfg, plan.p, plan.indirection.depth,
                                  1 << 16)
        for spec, lp in zip(specs[:-1], levels):
            assert spec.ruler_frac == lp.frac
            # dynamic target = min(max(floor, frac*n_active), r_static)
            # with n_active <= cap: frac*n_active <= frac*cap <= r_static
            assert int(spec.ruler_frac * spec.cap) <= spec.r_static


def test_none_fraction_end_to_end_bounds():
    """ruler_fraction=None end to end on a tiny mesh: the run succeeds
    and the level-0 ruler count lands in
    [min_rulers_per_pe * p, r_static * p]."""
    succ, rank = instances.gen_list(512, gamma=1.0, seed=9)
    cfg = ListRankConfig(ruler_fraction=None, srs_rounds=1,
                         local_contraction=False)
    s_ref, r_ref = rank_list_seq(succ, rank)
    s, r, stats = rank_list_with_stats(succ, rank, mesh1(), cfg=cfg)
    np.testing.assert_array_equal(np.asarray(s), s_ref)
    np.testing.assert_array_equal(np.asarray(r), r_ref)
    plan = MeshPlan(pe_axes=("pe",), axis_sizes=(1,),
                    indirection=IndirectionSpec.direct(("pe",)))
    specs = api.build_specs(cfg, plan, 512, 512, 1)
    # "rulers" counts launched rulers (initial + restarts), each launch
    # bounded by r_static; at least the floor is always launched.
    assert stats["rulers"] >= cfg.min_rulers_per_pe
    assert stats["rulers"] <= specs[0].r_static * (1 + cfg.max_restarts)


# --------------------------------------------------------------------------
# algorithm / indirection selection
# --------------------------------------------------------------------------

def test_auto_algorithm_picks_pd_below_threshold():
    cfg = ListRankConfig(algorithm="auto", machine=ALPHA_HEAVY)
    assert tuner.choose_algorithm(cfg, p=16, d=2, m=1 << 10) == "doubling"
    cfg = ListRankConfig(algorithm="auto", machine=BETA_HEAVY)
    assert tuner.choose_algorithm(cfg, p=16, d=2, m=1 << 10) == "srs"
    # explicit algorithms pass through untouched
    assert tuner.choose_algorithm(ListRankConfig(algorithm="srs",
                                                 machine=ALPHA_HEAVY),
                                  16, 2, 1) == "srs"


def test_auto_algorithm_end_to_end():
    """Below the Corollary-1 regime the solver must run pointer
    doubling: zero chase rounds, pd rounds > 0 — and stay correct."""
    succ, rank = instances.gen_list(256, gamma=1.0, seed=4)
    s_ref, r_ref = rank_list_seq(succ, rank)
    cfg = ListRankConfig(algorithm="auto", machine=ALPHA_HEAVY,
                         local_contraction=False)
    s, r, stats = rank_list_with_stats(succ, rank, mesh1(), cfg=cfg)
    np.testing.assert_array_equal(np.asarray(s), s_ref)
    np.testing.assert_array_equal(np.asarray(r), r_ref)
    assert stats["rounds"] == 0 and stats["pd_rounds"] > 0


def test_choose_indirection_follows_the_model():
    # startup-dominated machine: indirection amortizes p startups
    cfg = ListRankConfig(machine=ALPHA_HEAVY)
    spec = tuner.choose_indirection(cfg, ("row", "col"), (64, 64), 1 << 22)
    assert spec.depth == 2
    # volume-dominated machine: direct delivery avoids the 2x volume
    cfg = ListRankConfig(machine=BETA_HEAVY)
    spec = tuner.choose_indirection(cfg, ("row", "col"), (64, 64), 1 << 22)
    assert spec.depth == 1
    # a 1-axis mesh only admits direct delivery
    cfg = ListRankConfig(machine=ALPHA_HEAVY)
    spec = tuner.choose_indirection(cfg, ("pe",), (256,), 1 << 22)
    assert spec.hops == (("pe",),)


def test_candidates_exclude_size1_axes_from_hops():
    """A hop over a one-PE group is a real collective that moves
    nothing — size-1 axes must not appear in grid/topology hops nor be
    picked as the intra-node axis."""
    cands = dict((name, (spec, intra)) for name, spec, intra in
                 tuner.candidate_indirections(("a", "b", "c"), (4, 4, 1)))
    assert cands["grid"][0].hops == (("b",), ("a",))
    assert cands["topology"][1] == ("b",)
    # all axes size 1 except one -> direct only
    only = tuner.candidate_indirections(("a", "b"), (1, 8))
    assert [name for name, _, _ in only] == ["direct"]


def test_auto_indirection_end_to_end():
    succ, rank = instances.gen_list(256, gamma=1.0, seed=6)
    s_ref, r_ref = rank_list_seq(succ, rank)
    cfg = ListRankConfig(auto_indirection=True, srs_rounds=1,
                         local_contraction=False)
    s, r, _ = rank_list_with_stats(succ, rank, mesh1(), cfg=cfg)
    np.testing.assert_array_equal(np.asarray(s), s_ref)
    np.testing.assert_array_equal(np.asarray(r), r_ref)


# --------------------------------------------------------------------------
# build_specs consistency (satellite: p used, log p in max_rounds)
# --------------------------------------------------------------------------

def test_build_specs_max_rounds_has_log_p_term():
    cfg = ListRankConfig(srs_rounds=1)
    small = api.build_specs(cfg, plan_of(p=4), 1 << 12, 1 << 14, 4)
    big = api.build_specs(cfg, plan_of(p=1024), 1 << 12, 1 << 22, 4)
    assert big[0].max_rounds > small[0].max_rounds
    expect = int(cfg.max_round_slack * (32.0 + math.log2(1024)) + 256)
    assert big[0].max_rounds == expect


def test_build_specs_consistency():
    cfg = ListRankConfig(srs_rounds=2, ruler_fraction=None)
    plan = plan_of(p=16)
    m, n = 1 << 12, 1 << 16
    specs = api.build_specs(cfg, plan, m, n, term_bound=4)
    assert len(specs) == cfg.srs_rounds + 1
    assert specs[-1].base and not any(s.base for s in specs[:-1])
    cap = m
    for s in specs[:-1]:
        assert s.cap == cap
        assert s.r_static >= cfg.min_rulers_per_pe
        assert 0.0 < s.ruler_frac <= 1.0
        assert s.cap_sub <= s.cap
        assert all(c >= cfg.min_capacity for c in s.mail_caps)
        assert len(s.mail_caps) == plan.indirection.depth
        assert s.queue_cap >= 2 * sum(
            plan.hop_size(h) * c
            for h, c in zip(plan.indirection.hops, s.mail_caps))
        assert s.max_restarts == cfg.max_restarts
        cap = s.cap_sub
    assert specs[-1].cap == cap
    assert specs[-1].max_rounds >= int(math.log2(n))


def test_max_restarts_threads_into_levelspec():
    cfg = ListRankConfig(max_restarts=7)
    specs = api.build_specs(cfg, plan_of(), 1 << 10, 1 << 14, 4)
    assert all(s.max_restarts == 7 for s in specs)
