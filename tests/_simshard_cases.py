"""Shared case list + record schema for the golden bit-identity pins.

The same cases run twice: once on a real 8-device mesh (subprocess,
``tests/_golden_multi.py`` — that run's records are committed under
``tests/golden/``) and once on the simshard virtual-PE backend
in-process (``tests/test_simshard_golden.py``). The pin: solve output
bytes AND the per-attempt capacity-escalation path are identical.
"""
import hashlib
import json
import pathlib

import numpy as np

from repro.core.listrank import ListRankConfig, instances

#: the golden mesh: 8 PEs on one flat axis (both backends).
AXES = ("pe",)
SHAPE = (8,)

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"


def golden_cases():
    """(name, succ, rank, cfg) per case — seeds x families at p=8."""
    base = ListRankConfig(srs_rounds=1, local_contraction=True)
    cases = []
    # random permutation lists (paper's List(n, gamma=1)), two seeds
    for seed in (1, 2):
        s, r = instances.gen_list(512, gamma=1.0, seed=seed)
        cases.append((f"list-g1-s{seed}", s, r, base))
    # GNM-like / RGG2D-like BFS-tree Euler tours
    for fam, loc in (("gnm", False), ("rgg2d", True)):
        s, r, _ = instances.gen_euler_tour(257, seed=3, locality=loc)
        s, r = instances.pad_to_multiple(s, r, 8)
        cases.append((f"{fam}-tour-s3", s, r, base))
    # ±1-weighted forest tour through two recursion levels
    s, r, _ = instances.gen_euler_tour(257, seed=4, locality=True,
                                       weighted=True, num_trees=5)
    s, r = instances.pad_to_multiple(s, r, 8)
    cases.append(("euler-forest-s4", s, r, base.with_(srs_rounds=2)))
    # float32 weights exercise the bitcast wire path end to end
    s, r = instances.gen_random_lists(512, num_lists=11, seed=5,
                                      weighted=True)
    cases.append(("random-float-s5", s, r.astype(np.float32), base))
    # deliberately starved sub-store: the targeted retry ladder fires
    # (3 attempts, sub->global widening) and must escalate IDENTICALLY
    # on both backends
    s, r = instances.gen_list(512, gamma=1.0, seed=6)
    cases.append(("escalate-s6", s, r,
                  base.with_(sub_capacity_slack=0.05)))
    return cases


def case_record(succ_out, rank_out, stats) -> dict:
    """The byte-identity record of one solve: output hashes + the
    per-attempt escalation path (+ full counter dict, also pinned)."""
    succ_np = np.asarray(succ_out)
    rank_np = np.asarray(rank_out)
    counters = {k: v for k, v in sorted(stats.items())
                if isinstance(v, int)}
    return {
        "n": int(succ_np.shape[0]),
        "succ_sha256": hashlib.sha256(
            succ_np.astype(np.int32).tobytes()).hexdigest(),
        "rank_dtype": str(rank_np.dtype),
        "rank_sha256": hashlib.sha256(rank_np.tobytes()).hexdigest(),
        "attempts": int(stats["attempts"]),
        "scales_log": stats["scales_log"],
        "counters": counters,
    }


def load_golden(name: str) -> dict:
    return json.loads((GOLDEN_DIR / f"{name}.json").read_text())
