"""Fault-injection + recovery tests for the level-resumable solver
(``repro.core.listrank.resume`` + ``runtime.fault_tolerance.
SolveSupervisor``), in-process on the simshard backend at the golden
mesh shape (p=8) so every recovery path pins byte-identity against the
committed mesh goldens (tests/golden/).

Marked ``faultinject`` — CI runs ``-m faultinject`` as its own job; the
mesh-backend + cross-backend (elastic restore) half lives in
``tests/_subprocess_smoke.py`` suite ``faultinject`` (see TESTING.md).
"""
import os
import signal

import numpy as np
import pytest

from _simshard_cases import AXES, SHAPE, case_record, golden_cases, load_golden
from repro.checkpoint import Checkpointer, CheckpointWriteError
from repro.core.listrank import (FaultSpec, SolveExhausted,
                                 rank_list_with_stats, sim_mesh, tuner)
from repro.core.listrank.config import ListRankConfig
from repro.runtime.fault_tolerance import (Preempted, SolveSupervisor,
                                           SolveSupervisorConfig)

pytestmark = pytest.mark.faultinject

CASES = {name: (s, r, cfg) for name, s, r, cfg in golden_cases()}


def mesh8():
    return sim_mesh(SHAPE, AXES)


def sup(tmp_path, **kw):
    return SolveSupervisor(SolveSupervisorConfig(
        ckpt_dir=str(tmp_path / "ckpt"), **kw))


def counters_of(stats):
    return {k: v for k, v in sorted(stats.items())
            if isinstance(v, int) and k != "attempts"}


def escalated(cfg, level, stat):
    """The per-level scale vector after one escalation of ``stat`` at
    ``level`` — what an injected overflow there leaves behind."""
    base = tuner.normalize_level_scales(tuner.CapacityScales(),
                                        cfg.srs_rounds + 1)
    return tuner.escalate_levels(base, level, {stat: 1})


# --------------------------------------------------------------------------
# injected overflows: level resume + escalation, bit-identity
# --------------------------------------------------------------------------

def test_overflow_at_chase_level_resumes_and_matches():
    """Forced chase overflow at descend@0: the stage re-runs with only
    the chase family escalated; ranks match the committed golden and
    the full counters match a straight-through solve that starts from
    the escalated scales (resume == straight-through, bit for bit)."""
    s, r, cfg = CASES["list-g1-s1"]
    gold = load_golden("list-g1-s1")
    sf, rf, stats = rank_list_with_stats(
        s, r, mesh8(), cfg=cfg,
        inject=FaultSpec("overflow", stage="descend", level=0,
                         family="chase"))
    rec = case_record(sf, rf, stats)
    assert rec["succ_sha256"] == gold["succ_sha256"]
    assert rec["rank_sha256"] == gold["rank_sha256"]
    assert stats["attempts"] == 2
    assert stats["scales_log"].split(";")[1].startswith("chase=2")
    assert stats["recovery"]["injected"] == ("overflow:chase:descend@0",)
    assert stats["stage_log"].count("descend@0!overflow") == 1
    assert stats["stage_log"].count("descend@0") == 1

    sf2, rf2, stats2 = rank_list_with_stats(
        s, r, mesh8(), cfg=cfg,
        initial_scales=escalated(cfg, 0, "dropped"))
    assert np.array_equal(np.asarray(sf), np.asarray(sf2))
    assert np.array_equal(np.asarray(rf), np.asarray(rf2))
    assert counters_of(stats) == counters_of(stats2)


def test_overflow_at_base_level_does_not_reexecute_chase_levels():
    """Forced gather overflow at the base level of a two-level
    recursion: only base@2 re-runs (levels < 2 execute exactly once),
    the escalation is tagged with its level, and the result is
    bit-identical to the straight-through escalated solve."""
    s, r, cfg = CASES["euler-forest-s4"]
    gold = load_golden("euler-forest-s4")
    sf, rf, stats = rank_list_with_stats(
        s, r, mesh8(), cfg=cfg,
        inject=FaultSpec("overflow", stage="base", family="gather"))
    rec = case_record(sf, rf, stats)
    assert rec["succ_sha256"] == gold["succ_sha256"]
    assert rec["rank_sha256"] == gold["rank_sha256"]
    assert stats["attempts"] == 2
    assert stats["scales_log"].split(";")[1].endswith("@L2")
    log = stats["stage_log"]
    for label in ("prep", "descend@0", "descend@1", "ascend@1", "ascend@0",
                  "post"):
        assert log.count(label) == 1, (label, log)
    assert log.count("base@2!overflow") == 1 and log.count("base@2") == 1

    sf2, rf2, stats2 = rank_list_with_stats(
        s, r, mesh8(), cfg=cfg,
        initial_scales=escalated(cfg, 2, "undelivered"))
    assert np.array_equal(np.asarray(sf), np.asarray(sf2))
    assert np.array_equal(np.asarray(rf), np.asarray(rf2))
    assert counters_of(stats) == counters_of(stats2)


def test_exhaustion_error_is_structured():
    """SolveExhausted carries the full escalation path and the fatal
    stats/families of the failing attempt (satellite: structured
    exhaustion errors)."""
    s, r, cfg = CASES["escalate-s6"]
    with pytest.raises(SolveExhausted) as ei:
        rank_list_with_stats(s, r, mesh8(), cfg=cfg, max_retries=1)
    e = ei.value
    assert e.attempts == 2
    assert len(e.scales_log) == 2
    assert e.scales_log[0] == "chase=1,sub=1,gather=1,graph=1"
    assert e.fatal.get("sub_overflow", 0) > 0
    assert "sub" in e.families
    assert e.stats["sub_overflow"] > 0
    assert "escalation path" in str(e)


# --------------------------------------------------------------------------
# crash (PE loss) + corruption: checkpoint restore, no re-execution
# --------------------------------------------------------------------------

def test_pe_loss_at_base_restores_from_level_boundary(tmp_path):
    """An injected PE loss at the base level restores from the
    descend@0 boundary checkpoint: level 0 is not re-executed (asserted
    on the stage log and the per-stage collective counts), and the
    result is byte-identical to the committed golden."""
    s, r, cfg = CASES["list-g1-s1"]
    gold = load_golden("list-g1-s1")
    supervisor = sup(tmp_path)
    sf, rf, stats = rank_list_with_stats(
        s, r, mesh8(), cfg=cfg, supervisor=supervisor,
        inject=FaultSpec("pe_loss", stage="base"), stage_counters=True)
    assert case_record(sf, rf, stats) == gold
    rec = stats["recovery"]
    assert rec["restarts"] == 1
    assert rec["resumed_from"] == 2          # boundary after descend@0
    assert rec["injected"] == ("pe_loss:base@1",)
    log = stats["stage_log"]
    assert log.count("prep") == 1 and log.count("descend@0") == 1
    assert log.count("base@1!InjectedFault") == 1 and log.count("base@1") == 1
    # collective-count regression: each committed stage traced exactly
    # once — a resume must not re-execute the collectives of levels < k.
    labels = [lbl for lbl, _ in stats["stage_collectives"]]
    assert labels == ["prep", "descend@0", "base@1", "ascend@0", "post"]
    counts = dict(stats["stage_collectives"])
    assert dict(counts["descend@0"]).get("all_to_all", 0) > 0


def test_pe_loss_without_checkpoint_restarts_from_scratch():
    """No supervisor: a crash falls back to a scratch restart (bounded
    by max_retries) and still reproduces the golden bytes."""
    s, r, cfg = CASES["list-g1-s1"]
    gold = load_golden("list-g1-s1")
    sf, rf, stats = rank_list_with_stats(
        s, r, mesh8(), cfg=cfg, inject=FaultSpec("pe_loss", stage="base"))
    assert case_record(sf, rf, stats) == gold
    assert stats["recovery"]["restarts"] == 1
    assert stats["stage_log"].count("prep") == 2  # scratch restart


def test_corruption_detected_and_recovered(tmp_path):
    """A corrupted store plane after descend@0 is caught by boundary
    validation BEFORE it is checkpointed; the driver restores the prep
    boundary and re-runs the level cleanly."""
    s, r, cfg = CASES["list-g1-s1"]
    gold = load_golden("list-g1-s1")
    supervisor = sup(tmp_path)
    sf, rf, stats = rank_list_with_stats(
        s, r, mesh8(), cfg=cfg, supervisor=supervisor,
        inject=FaultSpec("corrupt", stage="descend", level=0, pe=3,
                         plane="succ"))
    assert case_record(sf, rf, stats) == gold
    rec = stats["recovery"]
    assert rec["restarts"] == 1
    assert rec["resumed_from"] == 1          # boundary after prep
    assert rec["injected"] == ("corrupt:descend@0",)
    assert stats["stage_log"].count("descend@0!CorruptedState") == 1
    assert stats["stage_log"].count("prep") == 1


# --------------------------------------------------------------------------
# preemption: SIGTERM-clean exit + restore-on-restart
# --------------------------------------------------------------------------

def test_preemption_mid_solve_checkpoints_and_resumes(tmp_path):
    """Preemption after descend@0 writes a blocking checkpoint and
    raises Preempted; a fresh supervisor on the same directory resumes
    from that boundary and the finished solve is byte-identical to the
    committed golden — counters included (elastic restore is exact)."""
    s, r, cfg = CASES["list-g1-s1"]
    gold = load_golden("list-g1-s1")
    supervisor = sup(tmp_path)
    with pytest.raises(Preempted):
        rank_list_with_stats(
            s, r, mesh8(), cfg=cfg, supervisor=supervisor,
            inject=FaultSpec("preempt", stage="descend", level=0))
    assert supervisor.stats["preempted"] == 1
    assert supervisor.ckpt.latest_step() == 2
    assert supervisor.latest_meta()["idx"] == 2

    resumed = sup(tmp_path)
    sf, rf, stats = rank_list_with_stats(s, r, mesh8(), cfg=cfg,
                                         supervisor=resumed)
    assert case_record(sf, rf, stats) == gold
    assert stats["recovery"]["resumed_from"] == 2
    assert stats["stage_log"] == ("base@1", "ascend@0", "post")


def test_sigterm_sets_preempt_flag_and_exits_cleanly(tmp_path):
    """The real signal path: SIGTERM flips the supervisor flag and the
    driver exits with Preempted at the next boundary check."""
    s, r, cfg = CASES["list-g1-s1"]
    supervisor = sup(tmp_path)
    old = {sig: signal.getsignal(sig)
           for sig in (signal.SIGTERM, signal.SIGINT)}
    try:
        supervisor.install_signal_handlers()
        os.kill(os.getpid(), signal.SIGTERM)
        assert supervisor.preempted
        with pytest.raises(Preempted):
            rank_list_with_stats(s, r, mesh8(), cfg=cfg,
                                 supervisor=supervisor)
    finally:
        for sig, h in old.items():
            signal.signal(sig, h)
    # nothing ran, nothing checkpointed; a later run starts clean
    assert supervisor.ckpt.latest_step() is None


def test_supervisor_stats_threaded_into_host_stats(tmp_path):
    """Satellite: Supervisor accounting rides in host_stats["recovery"]
    — and never perturbs the pinned integer counters (it is a dict)."""
    s, r, cfg = CASES["list-g1-s1"]
    gold = load_golden("list-g1-s1")
    supervisor = sup(tmp_path)
    sf, rf, stats = rank_list_with_stats(s, r, mesh8(), cfg=cfg,
                                         supervisor=supervisor)
    assert case_record(sf, rf, stats) == gold
    rec = stats["recovery"]
    assert rec["checkpoints"] == 4           # one per interior boundary
    assert rec["restarts"] == 0 and rec["preempted"] == 0
    assert rec["resumed_from"] == -1 and rec["injected"] == ()


# --------------------------------------------------------------------------
# checkpointer hardening (satellites)
# --------------------------------------------------------------------------

def test_async_write_failure_surfaces_with_step(tmp_path, monkeypatch):
    ckpt = Checkpointer(tmp_path / "c", keep=3, async_save=True)
    state = {"x": np.arange(4)}
    ckpt.save(1, state)
    ckpt.wait()

    def boom(*a, **kw):
        raise OSError("disk on fire")

    monkeypatch.setattr(np, "savez", boom)
    ckpt.save(2, state)                      # background write will fail
    with pytest.raises(CheckpointWriteError) as ei:
        ckpt.save(3, state)                  # surfaces step 2's failure
    assert ei.value.step == 2
    assert "step 2" in str(ei.value)
    assert isinstance(ei.value.__cause__, OSError)
    monkeypatch.undo()
    ckpt.save(3, state, blocking=True)       # recoverable afterwards
    assert ckpt.latest_step() == 3


def test_gc_never_deletes_the_step_being_written(tmp_path):
    """Out-of-order publish: gc ranks steps by name, so a freshly
    written low-numbered step must be protected from its own gc."""
    ckpt = Checkpointer(tmp_path / "c", keep=2, async_save=False)
    state = {"x": np.arange(4)}
    ckpt.save(5, state)
    ckpt.save(6, state)
    ckpt.save(1, state)                      # older step than the kept set
    dirs = sorted(d.name for d in (tmp_path / "c").glob("step_*"))
    assert "step_00000001" in dirs           # protected, not gc'd
    (ckpt.restore(1, {"x": np.zeros(4, np.int64)}))  # and restorable


# --------------------------------------------------------------------------
# sampled-splitter capacity estimation (satellite of the tentpole)
# --------------------------------------------------------------------------

def test_estimation_detects_destination_skew():
    """A hotspot instance (most successors owned by PE 0) must raise
    the estimated hop slack well above the uniform ~guard level."""
    n, p = 512, 8
    m = n // p
    rng = np.random.default_rng(0)
    succ = rng.integers(0, m, size=n)        # everything points at PE 0
    succ[::7] = rng.integers(0, n, size=len(succ[::7]))
    from repro.core.listrank.exchange import MeshPlan
    plan = MeshPlan.from_mesh(mesh8(), AXES, None)
    cfg = ListRankConfig()
    est = tuner.estimate_capacities(succ, plan, m, cfg)
    uni = tuner.estimate_capacities(
        rng.permutation(n).astype(np.int64), plan, m, cfg)
    assert est.hop_slack[0] > 2 * uni.hop_slack[0]
    assert est.max_frac[0] > 0.5
    assert est.sample_size == min(cfg.estimation_sample, n)


def test_estimation_end_to_end_first_attempt_clean():
    """capacity_estimation=True solves the golden case in one attempt
    with byte-identical ranks (capacities never change results)."""
    s, r, cfg = CASES["list-g1-s1"]
    gold = load_golden("list-g1-s1")
    sf, rf, stats = rank_list_with_stats(
        s, r, mesh8(), cfg=cfg.with_(capacity_estimation=True))
    rec = case_record(sf, rf, stats)
    assert rec["succ_sha256"] == gold["succ_sha256"]
    assert rec["rank_sha256"] == gold["rank_sha256"]
    assert stats["attempts"] == 1


def test_estimated_specs_track_skew_in_mail_caps():
    """build_specs consumes the estimate: a skewed instance gets larger
    mailboxes than the static slack would give, a uniform one does not
    explode."""
    from repro.core.listrank import api
    from repro.core.listrank.exchange import MeshPlan
    n, p = 512, 8
    m = n // p
    rng = np.random.default_rng(1)
    skew = rng.integers(0, m, size=n)
    plan = MeshPlan.from_mesh(mesh8(), AXES, None)
    cfg = ListRankConfig(srs_rounds=1)
    est = tuner.estimate_capacities(skew, plan, m, cfg)
    static = api.build_specs(cfg, plan, m, n, 4)
    sized = api.build_specs(cfg, plan, m, n, 4, estimate=est)
    # gather caps scale with the store capacity, so the measured skew
    # shows even at this instance size (mailboxes sit on min_capacity)
    assert sized[0].gather_req_cap[0] > static[0].gather_req_cap[0]
    assert sized[0].mail_caps[0] >= static[0].mail_caps[0]
    assert sized[0].cap_sub == static[0].cap_sub  # sub stays analytic


# --------------------------------------------------------------------------
# fault spec hygiene
# --------------------------------------------------------------------------

def test_fault_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec("meteor")
    with pytest.raises(ValueError):
        FaultSpec("overflow", family="warp")
    f = FaultSpec("overflow", stage="descend", level=1, family="sub")
    assert f.level == 1
