"""Subprocess body for multi-PE exchange tests (8 virtual devices).

Run as: python tests/_exchange_multi.py — exits nonzero on any failure.
Covers, on a (2, 4) mesh with direct / grid / topology indirection:

  * route: delivery equals a numpy multiset oracle; packed and
    unpacked wire paths are bit-identical row-for-row,
  * capacity overflow: leftovers re-queue to completion, nothing lost
    or duplicated,
  * remote_gather: answers correct with/without dedup over 2-hop
    indirection — which exercises the row-index source reconstruction
    (no 'src' leaf on the wire),
  * collective counts on the real mesh: packed route = 1 all_to_all
    per hop.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.core.listrank import introspect  # noqa: E402
from repro.core.listrank.config import IndirectionSpec  # noqa: E402
from repro.core.listrank.exchange import (MeshPlan, compact_queue,  # noqa
                                          remote_gather, route)

AXES = ("row", "col")
P_ALL = P(AXES)
FAILURES = 0


def check(name, ok):
    global FAILURES
    print(("OK  " if ok else "FAIL") + " " + name)
    if not ok:
        FAILURES += 1


def specs():
    return {
        "direct": (None, 1),
        "grid": (IndirectionSpec.grid(AXES), 2),
        "topo": (IndirectionSpec.topology(("col",), ("row",)), 2),
    }


def gen_messages(p, q, seed):
    rng = np.random.default_rng(seed)
    return {
        "ia": rng.integers(-50, 50, p * q).astype(np.int32),
        "fb": rng.normal(size=p * q).astype(np.float32),
    }, rng.integers(0, p, p * q).astype(np.int32), \
        rng.integers(0, 2, p * q).astype(bool)


def run_route(mesh, plan, caps, payload, dest, valid):
    keys = sorted(payload.keys())

    def fn(*leaves):
        pl = dict(zip(keys, leaves[:-2]))
        d, dv, lo, st = route(plan, caps, pl, leaves[-2], leaves[-1])
        left = sum(jnp.sum(lv).astype(jnp.int32) for _, _, lv in lo)
        return d, dv, jax.lax.psum(left, AXES)

    args = [jnp.asarray(payload[k]) for k in keys] + [
        jnp.asarray(dest), jnp.asarray(valid)]
    m = jax.jit(compat.shard_map(
        fn, mesh, in_specs=tuple(P_ALL for _ in args),
        out_specs=({k: P_ALL for k in keys}, P_ALL, P())))
    d, dv, left = m(*args)
    return {k: np.asarray(v) for k, v in d.items()}, np.asarray(dv), int(left)


def rows_multiset(payload, dest, valid, pe_of_slot):
    """{pe: sorted list of (ia, fb_bits, dest) rows addressed to it}."""
    out = {}
    for i in np.flatnonzero(valid):
        out.setdefault(int(dest[i]), []).append(
            (int(payload["ia"][i]), int(payload["fb"][i].view(np.int32)),
             int(dest[i])))
    return {k: sorted(v) for k, v in out.items()}


def main():
    mesh = compat.make_mesh((2, 4), AXES)
    p = 8
    q = 32

    # ---- 1+2: oracle delivery + packed/unpacked bit-identity
    payload, dest, valid = gen_messages(p, q, seed=1)
    want = rows_multiset(payload, dest, valid, None)
    for name, (ind, hops) in specs().items():
        caps = [q] if hops == 1 else [q, 8 * q]
        outs = {}
        for packed in (True, False):
            plan = MeshPlan.from_mesh(mesh, AXES, ind, wire_packing=packed)
            d, dv, left = run_route(mesh, plan, caps, payload, dest, valid)
            outs[packed] = (d, dv)
            if packed:
                r = dv.shape[0] // p
                ok = left == 0
                for pe in range(p):
                    sl = slice(pe * r, (pe + 1) * r)
                    got = sorted(
                        (int(d["ia"][i]), int(d["fb"][i].view(np.int32)), pe)
                        for i in range(pe * r, (pe + 1) * r) if dv[i])
                    ok &= got == want.get(pe, [])
                check(f"route oracle {name}", ok)
        (d1, v1), (d2, v2) = outs[True], outs[False]
        ok = np.array_equal(v1, v2) and all(
            np.array_equal(d1[k].view(np.int32), d2[k].view(np.int32))
            for k in d1)
        check(f"route packed==unpacked {name}", ok)

    # ---- 3: tiny capacities — drain with leftover re-queue
    for name, (ind, hops) in specs().items():
        plan = MeshPlan.from_mesh(mesh, AXES, ind, wire_packing=True)
        caps = [3] * hops
        keys = sorted(payload.keys())

        def drain(*leaves):
            pl = dict(zip(keys, leaves[:-2]))
            d0, dest0, valid0 = pl, leaves[-2], leaves[-1]
            got = jnp.zeros((q * p,), jnp.int32)  # delivered ia values hist?
            # accumulate delivered (ia) counts per PE via python loop of
            # fixed trips (enough rounds to drain worst case)
            acc_ia = []
            acc_dv = []
            cur_pl, cur_d, cur_v = d0, dest0, valid0
            for _ in range(24):
                dlv, dv, lo, st = route(plan, caps, cur_pl, cur_d, cur_v)
                acc_ia.append(jnp.where(dv, dlv["ia"], -10 ** 6))
                acc_dv.append(dv)
                cur_pl, cur_d, cur_v, dropped = compact_queue(lo, q)
            rest = jax.lax.psum(jnp.sum(cur_v).astype(jnp.int32), AXES)
            return jnp.stack(acc_ia), jnp.stack(acc_dv), rest

        args = [jnp.asarray(payload[k]) for k in keys] + [
            jnp.asarray(dest), jnp.asarray(valid)]
        m = jax.jit(compat.shard_map(
            drain, mesh, in_specs=tuple(P_ALL for _ in args),
            out_specs=(P(None, AXES), P(None, AXES), P())))
        ia_rounds, dv_rounds, rest = m(*args)
        ia_rounds, dv_rounds = np.asarray(ia_rounds), np.asarray(dv_rounds)
        got_total = int(dv_rounds.sum())
        want_total = int(valid.sum())
        got_ia = sorted(ia_rounds[dv_rounds])
        want_ia = sorted(payload["ia"][valid])
        check(f"overflow drain {name}",
              int(rest) == 0 and got_total == want_total
              and got_ia == list(want_ia))

    # ---- 4: remote_gather answers over every spec (src reconstruction)
    rng = np.random.default_rng(3)
    n = p * q
    targets = rng.integers(0, n, n).astype(np.int32)
    gvalid = rng.integers(0, 2, n).astype(bool)
    for name, (ind, hops) in specs().items():
        for dedup in (True, False):
            for packed in (True, False):
                plan = MeshPlan.from_mesh(mesh, AXES, ind,
                                          wire_packing=packed)

                def gather(t, v):
                    me = plan.my_id().astype(jnp.int32)

                    def lookup(g, gv):
                        # owner-side table: val[g] = 3g+7, owner check
                        return {"val": g * 3 + 7,
                                "owner": jnp.zeros_like(g) + me}

                    out, answered, st = remote_gather(
                        plan, t, v, lambda g: g // q, lookup,
                        req_cap=[q * p] * hops, resp_cap=[q * p] * hops,
                        dedup=dedup)
                    return out, answered

                m = jax.jit(compat.shard_map(
                    gather, mesh, in_specs=(P_ALL, P_ALL),
                    out_specs=({"val": P_ALL, "owner": P_ALL}, P_ALL)))
                out, answered = m(jnp.asarray(targets), jnp.asarray(gvalid))
                out = {k: np.asarray(v) for k, v in out.items()}
                answered = np.asarray(answered)
                ok = np.array_equal(answered, gvalid)
                ok &= np.array_equal(out["val"][gvalid],
                                     targets[gvalid] * 3 + 7)
                ok &= np.array_equal(out["owner"][gvalid],
                                     targets[gvalid] // q)
                check(f"gather {name} dedup={dedup} packed={packed}", ok)

    # ---- 5: collective counts on the real mesh
    for name, (ind, hops) in specs().items():
        for packed, per_hop in ((True, 1), (False, 4)):
            plan = MeshPlan.from_mesh(mesh, AXES, ind, wire_packing=packed)
            keys = sorted(payload.keys())

            def fn(*leaves):
                pl = dict(zip(keys, leaves[:-2]))
                d, dv, _, _ = route(plan, [q] * hops, pl, leaves[-2],
                                    leaves[-1])
                return d, dv

            args = [jnp.asarray(payload[k]) for k in keys] + [
                jnp.asarray(dest), jnp.asarray(valid)]
            m = compat.shard_map(
                fn, mesh, in_specs=tuple(P_ALL for _ in args),
                out_specs=({k: P_ALL for k in keys}, P_ALL))
            counts = introspect.collective_counts(m, *args)
            check(f"collectives {name} packed={packed}",
                  counts.get("all_to_all", 0) == per_hop * hops)

    print("failures:", FAILURES)
    sys.exit(1 if FAILURES else 0)


if __name__ == "__main__":
    main()
