"""Oracle tests for the vectorized instance generators.

The generators in ``instances.py`` were rewritten from O(n) Python
loops (with per-element ``set``/``list.index`` lookups) to vectorized
numpy so paper-scale instances (>= 10^7 elements) are practical. The
original loop implementations are kept *here* as the reference oracle:
for small n and a fixed seed the vectorized output must be identical
bit for bit (both consume the identical RNG stream).
"""
import numpy as np
import pytest

from repro.core.listrank import instances


# --------------------------------------------------------------------------
# reference (seed) implementations — the pre-vectorization loop code
# --------------------------------------------------------------------------

def ref_gen_list(n, gamma, seed=0, num_lists=1):
    rng = np.random.default_rng(seed)
    labels = np.arange(n, dtype=np.int64)
    k = int(round(gamma * n))
    if k > 1:
        pos = rng.choice(n, size=k, replace=False)
        labels[pos] = labels[rng.permutation(pos)]
    succ = np.empty(n, dtype=np.int64)
    cuts = np.linspace(0, n, num_lists + 1).astype(np.int64)[1:]
    ends = set((cuts - 1).tolist())
    for j in range(n):
        if j in ends or j == n - 1:
            succ[labels[j]] = labels[j]
        else:
            succ[labels[j]] = labels[j + 1]
    idx = np.arange(n)
    rank = (succ != idx).astype(np.int64)
    return succ.astype(np.int32), rank.astype(np.int32)


def ref_gen_random_lists(n, num_lists, seed=0, weighted=False):
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    succ = np.empty(n, dtype=np.int64)
    cuts = (np.sort(rng.choice(np.arange(1, n), size=num_lists - 1,
                               replace=False))
            if num_lists > 1 else np.array([], dtype=np.int64))
    bounds = np.concatenate([[0], cuts, [n]])
    for a, b in zip(bounds[:-1], bounds[1:]):
        seg = perm[a:b]
        succ[seg[:-1]] = seg[1:]
        succ[seg[-1]] = seg[-1]
    idx = np.arange(n)
    if weighted:
        rank = rng.integers(0, 100, size=n).astype(np.int64)
        rank[succ == idx] = 0
    else:
        rank = (succ != idx).astype(np.int64)
    return succ.astype(np.int32), rank.astype(np.int32)


def ref_gen_euler_tour(n_nodes, seed=0, locality=False):
    rng = np.random.default_rng(seed)
    parent = instances._random_tree_parents(n_nodes, rng, locality)
    n_arcs = 2 * (n_nodes - 1)
    if n_arcs == 0:
        return (np.zeros(0, np.int32), np.zeros(0, np.int32),
                np.zeros((0, 2), np.int64))
    order = np.argsort(parent[1:], kind="stable")
    children = [[] for _ in range(n_nodes)]
    for c in (order + 1):
        children[parent[c]].append(int(c))

    def down_id(c):
        return 2 * (c - 1)

    def up_id(c):
        return 2 * (c - 1) + 1

    succ = np.empty(n_arcs, dtype=np.int64)
    for c in range(1, n_nodes):
        ch = children[c]
        succ[down_id(c)] = down_id(ch[0]) if ch else up_id(c)
        q = parent[c]
        sibs = children[q]
        j = sibs.index(c)
        if j + 1 < len(sibs):
            succ[up_id(c)] = down_id(sibs[j + 1])
        elif q == 0:
            succ[up_id(c)] = up_id(c)
        else:
            succ[up_id(c)] = up_id(q)
    idx = np.arange(n_arcs)
    rank = (succ != idx).astype(np.int64)
    arcs = np.empty((n_arcs, 2), dtype=np.int64)
    for c in range(1, n_nodes):
        arcs[down_id(c)] = (parent[c], c)
        arcs[up_id(c)] = (c, parent[c])
    return succ.astype(np.int32), rank.astype(np.int32), arcs


# --------------------------------------------------------------------------
# oracle equality
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n,gamma,num_lists,seed", [
    (1, 0.0, 1, 0), (2, 1.0, 1, 1), (17, 0.5, 1, 2), (64, 0.0, 1, 3),
    (64, 1.0, 1, 4), (128, 0.3, 5, 5), (100, 1.0, 7, 6), (5, 1.0, 5, 7),
    (256, 0.9, 3, 8),
])
def test_gen_list_matches_loop_reference(n, gamma, num_lists, seed):
    s_ref, r_ref = ref_gen_list(n, gamma, seed=seed, num_lists=num_lists)
    s, r = instances.gen_list(n, gamma, seed=seed, num_lists=num_lists)
    np.testing.assert_array_equal(s, s_ref)
    np.testing.assert_array_equal(r, r_ref)


@pytest.mark.parametrize("n,num_lists,weighted,seed", [
    (1, 1, False, 0), (2, 2, False, 1), (64, 1, False, 2),
    (64, 9, True, 3), (128, 17, True, 4), (200, 2, False, 5),
])
def test_gen_random_lists_matches_loop_reference(n, num_lists, weighted,
                                                 seed):
    s_ref, r_ref = ref_gen_random_lists(n, num_lists, seed=seed,
                                        weighted=weighted)
    s, r = instances.gen_random_lists(n, num_lists, seed=seed,
                                      weighted=weighted)
    np.testing.assert_array_equal(s, s_ref)
    np.testing.assert_array_equal(r, r_ref)


@pytest.mark.parametrize("n_nodes,locality,seed", [
    (1, False, 0), (2, False, 1), (3, True, 2), (50, False, 3),
    (50, True, 4), (173, False, 5), (173, True, 6), (400, True, 7),
])
def test_gen_euler_tour_matches_loop_reference(n_nodes, locality, seed):
    s_ref, r_ref, a_ref = ref_gen_euler_tour(n_nodes, seed=seed,
                                             locality=locality)
    s, r, a = instances.gen_euler_tour(n_nodes, seed=seed, locality=locality)
    np.testing.assert_array_equal(s, s_ref)
    np.testing.assert_array_equal(r, r_ref)
    np.testing.assert_array_equal(a, a_ref)


# --------------------------------------------------------------------------
# weighted tours and forests: oracle = per-node recomputation
# --------------------------------------------------------------------------

def node_depths_from_parent(parent):
    """Per-node depth by chasing the parent pointers (loop oracle)."""
    n = parent.shape[0]
    depth = np.zeros(n, np.int64)
    for c in range(n):
        d, x = 0, c
        while parent[x] != x:
            x = parent[x]
            d += 1
        depth[c] = d
    return depth


def parents_of_instance(n_nodes, seed, locality, num_trees):
    """The parent array behind a gen_euler_tour instance (the public
    generator shares the tour's RNG stream by construction)."""
    return instances.gen_tree_parents(n_nodes, seed=seed,
                                      locality=locality,
                                      num_trees=num_trees)


@pytest.mark.parametrize("n_nodes,locality,num_trees,seed", [
    (2, False, 1, 0), (40, False, 1, 1), (40, True, 1, 2),
    (60, False, 4, 3), (60, True, 7, 4), (9, False, 9, 5), (150, True, 3, 6),
])
def test_weighted_tour_recovers_depth(n_nodes, locality, num_trees, seed):
    """Ranking the ±1-weighted tour must recover every node's depth:
    depth(c) = 2 - rank±(down(c)) (see gen_euler_tour docstring) —
    checked against per-node parent chasing."""
    from repro.core.listrank.sequential import rank_list_seq
    succ, rank, arcs = instances.gen_euler_tour(
        n_nodes, seed=seed, locality=locality, weighted=True,
        num_trees=num_trees)
    parent = parents_of_instance(n_nodes, seed, locality, num_trees)
    depth_ref = node_depths_from_parent(parent)
    _, r = rank_list_seq(succ, rank)
    nonroot = parent != np.arange(n_nodes)
    for c in np.nonzero(nonroot)[0]:
        assert 2 - r[2 * (c - 1)] == depth_ref[c], f"node {c}"
    # down-arcs carry +1, up-arcs -1, terminals/dummies 0
    idx = np.arange(succ.shape[0])
    term = succ == idx
    np.testing.assert_array_equal(rank[term], 0)
    np.testing.assert_array_equal(rank[~term & (idx % 2 == 0)], 1)
    np.testing.assert_array_equal(rank[~term & (idx % 2 == 1)], -1)


@pytest.mark.parametrize("n_nodes,locality,num_trees,seed", [
    (50, False, 5, 0), (50, True, 2, 1), (100, False, 10, 2),
    (7, True, 7, 3),
])
def test_forest_tour_structure(n_nodes, locality, num_trees, seed):
    """Every tree of the forest contributes one complete cut tour: per
    tree 2*(size-1) arcs chase to a single terminal, and the remaining
    slots are the roots' dummies — checked per node."""
    from repro.core.listrank.sequential import rank_list_seq
    succ, rank, arcs = instances.gen_euler_tour(
        n_nodes, seed=seed, locality=locality, num_trees=num_trees)
    parent = parents_of_instance(n_nodes, seed, locality, num_trees)
    nodes = np.arange(n_nodes)
    roots = nodes[parent == nodes]
    assert roots.size == num_trees
    # tree membership per node (loop recomputation)
    root_of = np.empty(n_nodes, np.int64)
    for c in range(n_nodes):
        x = c
        while parent[x] != x:
            x = parent[x]
        root_of[c] = x
    sizes = {int(r): int(np.sum(root_of == r)) for r in roots}
    s_out, r_out = rank_list_seq(succ, rank)
    idx = np.arange(succ.shape[0])
    for r in roots:
        members = nodes[(root_of == r) & (nodes != r)]
        tree_arcs = np.concatenate(
            [2 * (members - 1), 2 * (members - 1) + 1]) if members.size \
            else np.zeros(0, np.int64)
        # all arcs of one tree end at one shared terminal...
        assert len(set(s_out[tree_arcs].tolist())) <= 1
        # ...and their unweighted ranks are a permutation of the tour
        # positions 0..2(size-1)-1
        np.testing.assert_array_equal(
            np.sort(r_out[tree_arcs]), np.arange(2 * (sizes[int(r)] - 1)))
    # dummy slots of non-0 roots self-loop and carry (r, r) arcs
    for r in roots[roots > 0]:
        for a in (2 * (r - 1), 2 * (r - 1) + 1):
            assert succ[a] == a and rank[a] == 0
            np.testing.assert_array_equal(arcs[a], (r, r))


def test_forest_rng_stream_backward_compatible():
    """num_trees=1 / weighted=False must reproduce the pre-extension
    instance bit for bit (the extra draws happen after the tree)."""
    s0, r0, a0 = ref_gen_euler_tour(80, seed=9, locality=True)
    s1, r1, a1 = instances.gen_euler_tour(80, seed=9, locality=True,
                                          weighted=False, num_trees=1)
    np.testing.assert_array_equal(s0, s1)
    np.testing.assert_array_equal(r0, r1)
    np.testing.assert_array_equal(a0, a1)
    # weighted shares the tour structure, only the weights change
    s2, r2, a2 = instances.gen_euler_tour(80, seed=9, locality=True,
                                          weighted=True)
    np.testing.assert_array_equal(s0, s2)
    np.testing.assert_array_equal(a0, a2)
    assert set(np.unique(r2)) <= {-1, 0, 1}


# --------------------------------------------------------------------------
# edge-list generators (graphalg input families)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n,e,locality,k,seed", [
    (40, 60, False, 1, 0), (40, 60, True, 1, 1),
    (50, 55, False, 5, 2), (50, 55, True, 3, 3),
    (12, 11, False, 1, 4), (16, 8, False, 8, 5), (7, 0, True, 7, 6),
])
def test_gen_graph_edges_component_count(n, e, locality, k, seed):
    """Oracle check against a host union-find: exactly the requested
    component count, every endpoint in range, no self-loops."""
    from _graph_oracles import union_find_labels
    edges = instances.gen_graph_edges(n, e, seed=seed, locality=locality,
                                      num_components=k)
    assert edges.shape == (e, 2)
    if e:
        assert ((edges >= 0) & (edges < n)).all()
        assert (edges[:, 0] != edges[:, 1]).all()
    labels = union_find_labels(n, edges)
    assert np.unique(labels).size == k


def test_gen_graph_edges_locality():
    """The RGG2D-like model must give the block distribution a real
    locality edge over GNM (that is its entire point)."""
    n, e, p = 1 << 12, 1 << 13, 16
    m = n // p
    def cross_fraction(edges):
        return float(np.mean(edges[:, 0] // m != edges[:, 1] // m))
    gnm = instances.gen_graph_edges(n, e, seed=0, locality=False)
    rgg = instances.gen_graph_edges(n, e, seed=0, locality=True)
    assert cross_fraction(rgg) < 0.2 < cross_fraction(gnm)


def test_gen_graph_edges_deterministic_and_validates():
    np.testing.assert_array_equal(
        instances.gen_graph_edges(30, 50, seed=7, num_components=2),
        instances.gen_graph_edges(30, 50, seed=7, num_components=2))
    with pytest.raises(ValueError, match="cannot connect"):
        instances.gen_graph_edges(10, 5, num_components=1)
    with pytest.raises(ValueError, match="num_components"):
        instances.gen_graph_edges(4, 10, num_components=5)
    with pytest.raises(ValueError, match="n_nodes"):
        instances.gen_graph_edges(0, 0)


# --------------------------------------------------------------------------
# structural sanity at a size the loop version could not handle quickly
# --------------------------------------------------------------------------

def test_generators_scale():
    n = 1 << 20
    s, r = instances.gen_list(n, gamma=1.0, seed=0)
    assert s.shape == (n,) and np.sum(s == np.arange(n)) == 1
    s, r = instances.gen_random_lists(n, num_lists=64, seed=1)
    assert np.sum(s == np.arange(n)) == 64
    s, r, arcs = instances.gen_euler_tour(n // 4, seed=2, locality=True)
    n_arcs = 2 * (n // 4 - 1)
    assert s.shape == (n_arcs,) and arcs.shape == (n_arcs, 2)
    # the tour visits every arc exactly once: ranks on the single list
    # reaching the root-return arc form a permutation prefix
    assert np.sum(s == np.arange(n_arcs)) == 1
    # edge generator at scale: one vectorized pass
    edges = instances.gen_graph_edges(n // 4, n // 2, seed=3, locality=True,
                                      num_components=16)
    assert edges.shape == (n // 2, 2) and (edges[:, 0] != edges[:, 1]).all()
