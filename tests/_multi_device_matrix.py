"""Subprocess body for multi-PE list-ranking tests (8 virtual devices).

Run as: python tests/_multi_device_matrix.py — exits nonzero on any
mismatch against the sequential oracle. Must set XLA_FLAGS before jax.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import jax  # noqa: E402

from repro import compat  # noqa: E402
from repro.core.listrank import (IndirectionSpec, ListRankConfig,  # noqa
                                 instances, rank_list_seq,
                                 rank_list_with_stats)


def main():
    mesh = compat.make_mesh((2, 4), ("row", "col"))
    base = ListRankConfig(srs_rounds=1, local_contraction=False)
    grid = IndirectionSpec.grid(("row", "col"))
    topo = IndirectionSpec.topology(("col",), ("row",))
    n = 1024
    sg1, rg1 = instances.gen_list(n, gamma=1.0, seed=1)
    sg0, rg0 = instances.gen_list(n, gamma=0.0, seed=2)
    sml, rml = instances.gen_random_lists(n, num_lists=11, seed=4,
                                          weighted=True)
    se, re_, _ = instances.gen_euler_tour(n // 2 + 1, seed=6, locality=True)
    se, re_ = instances.pad_to_multiple(se, re_, 8)
    sg, rg = instances.gen_euler_tour(n // 2 + 1, seed=7, locality=False)[:2]
    sg, rg = instances.pad_to_multiple(sg, rg, 8)
    sw, rw = instances.gen_euler_tour(n // 2 + 1, seed=8, locality=True,
                                      weighted=True, num_trees=5)[:2]
    sw, rw = instances.pad_to_multiple(sw, rw, 8)

    cases = [
        ("srs1 direct", sg1, rg1, base, None),
        ("srs2 contract", sg1, rg1,
         base.with_(srs_rounds=2, local_contraction=True), None),
        ("srs1 grid", sg1, rg1, base, grid),
        ("srs1 topo", sg1, rg1, base, topo),
        ("srs2 grid contract", sg0, rg0,
         base.with_(srs_rounds=2, local_contraction=True), grid),
        ("reversal", sg1, rg1, base.with_(avoid_reversal=False), None),
        ("doubling grid", sg1, rg1, base.with_(algorithm="doubling"), grid),
        ("weighted multilist", sml, rml,
         base.with_(srs_rounds=2, local_contraction=True), None),
        ("euler contract", se, re_, base.with_(local_contraction=True), None),
        # faithful Algorithm-1 direction handling (explicit reversal
        # preprocessing) on Euler-tour instances — both tree models,
        # plus a ±1-weighted forest tour through the reversal build
        ("euler rgg2d reversal", se, re_,
         base.with_(avoid_reversal=False), None),
        ("euler gnm reversal grid", sg, rg,
         base.with_(avoid_reversal=False, local_contraction=True), grid),
        ("euler weighted forest reversal", sw, rw,
         base.with_(avoid_reversal=False, srs_rounds=2), None),
        ("pallas contract", sg1, rg1,
         base.with_(local_contraction=True, use_pallas=True), None),
        ("srs1 unpacked wire", sg1, rg1, base.with_(wire_packing=False),
         None),
        ("srs1 grid unpacked", sg1, rg1, base.with_(wire_packing=False),
         grid),
        ("pallas mailbox pack", sg1, rg1, base.with_(use_pallas_pack=True),
         None),
    ]
    failures = 0
    for name, succ, rank, cfg, ind in cases:
        s_ref, r_ref = rank_list_seq(succ, rank)
        s, r, stats = rank_list_with_stats(succ, rank, mesh, cfg=cfg,
                                           indirection=ind)
        ok = (np.array_equal(np.asarray(s), s_ref)
              and np.array_equal(np.asarray(r), r_ref))
        print(("OK  " if ok else "FAIL") + f" {name} "
              f"rounds={stats['rounds'] // 8} msgs={stats['chase_msgs']}")
        failures += 0 if ok else 1

    # paper-theory checks (§2.2): rounds ~ n/r + 1; |sub| ~ r ln(n/r)
    cfg = base.with_(ruler_fraction=1 / 32)
    _, _, stats = rank_list_with_stats(sg1, rg1, mesh, cfg=cfg)
    rounds = stats["rounds"] // 8
    r_tot = 8 * max(4, int(n / 8 / 32))
    expect = n / r_tot + 1
    if not rounds <= 4 * expect:
        print(f"FAIL round bound: {rounds} vs expected ~{expect}")
        failures += 1
    import math
    sub_expect = r_tot * math.log(n / r_tot)
    if not stats["sub_size"] <= 3 * sub_expect + 64:
        print(f"FAIL sub size: {stats['sub_size']} vs ~{sub_expect}")
        failures += 1
    print("failures:", failures)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
