"""Sharding resolver: divisibility downgrades, axis reuse, rule order."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.runtime import sharding as shlib
from repro import compat


def mesh44():
    return compat.make_mesh((1, 1), ("data", "model"))


def fake_mesh(shape, names):
    """Abstract mesh for resolution tests (no devices needed)."""
    return compat.abstract_mesh(shape, names)


def test_divisible_dims_shard():
    mesh = fake_mesh((16, 16), ("data", "model"))
    spec = shlib.resolve_spec((256, 4096), ("batch", "mlp"), mesh)
    assert spec == P("data", "model")


def test_non_divisible_downgrades_with_report():
    mesh = fake_mesh((16, 16), ("data", "model"))
    rep = shlib.ResolveReport()
    spec = shlib.resolve_spec((49155, 64), ("vocab", "embed"), mesh,
                              name="emb", report=rep)
    assert spec == P(None, None)
    assert any("49155" in d for d in rep.downgrades)


def test_axis_used_once():
    mesh = fake_mesh((16, 16), ("data", "model"))
    # both dims want "model": only the first gets it
    spec = shlib.resolve_spec((4096, 4096), ("mlp", "mlp"), mesh)
    assert spec == P("model", None)


def test_candidate_fallback_order():
    mesh = fake_mesh((2, 16, 16), ("pod", "data", "model"))
    # batch prefers (pod, data) jointly = 32
    spec = shlib.resolve_spec((256,), ("batch",), mesh)
    assert spec == P(("pod", "data"))
    # batch=8 not divisible by 32 -> falls to data(16)? 8%16!=0 -> repl
    spec = shlib.resolve_spec((8,), ("batch",), mesh)
    assert spec == P(None)


def test_multipod_expert_rule():
    mesh = fake_mesh((2, 16, 16), ("pod", "data", "model"))
    spec = shlib.resolve_spec((384, 7168, 2048),
                              ("experts", "embed", "expert_mlp"), mesh)
    assert spec == P("data", None, "model")
