"""treealg subsystem tests (single-device mesh; multi-PE in
tests/_subprocess_smoke.py suite "treealg"): device tour vs the instances.py oracle, tree
statistics vs per-node DFS recomputation on every instance family, the
re-rooting orientation, and the batched front door's two contracts —
one solver invocation per batch, and a per-round collective count
identical to a single-instance solve (jaxpr inspection)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _tree_oracles import dfs_stats
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import treealg
from repro.core.listrank import (ListRankConfig, instances, introspect,
                                 rank_list_seq)
from repro.core.listrank import api as api_lib
from repro.core.listrank.exchange import MeshPlan
from repro.core.listrank.instances import gen_tree_parents
from repro.core.treealg import batch as batch_lib


def mesh1():
    return compat.make_mesh((1,), ("pe",))


CFG = ListRankConfig(srs_rounds=1, local_contraction=False)


# --------------------------------------------------------------------------
# device tour construction vs the host oracle
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n,locality,num_trees,seed", [
    (2, False, 1, 0), (64, False, 1, 1), (64, True, 1, 2),
    (65, True, 3, 3), (128, False, 9, 4), (5, False, 5, 5),
])
def test_build_tour_matches_oracle(n, locality, num_trees, seed):
    parent = gen_tree_parents(n, seed, locality, num_trees)
    succ, w, n_pad = treealg.build_tour(parent, mesh1(), cfg=CFG)
    succ_np = np.asarray(jax.device_get(succ))[:2 * n]
    np.testing.assert_array_equal(
        succ_np, treealg.oracle_tour(n, parent).astype(np.int32))
    # unit weights: 1 on tour arcs, 0 on terminals/dummies
    w_np = np.asarray(jax.device_get(w))[:2 * n]
    np.testing.assert_array_equal(w_np, (succ_np != np.arange(2 * n)))


@pytest.mark.parametrize("variant", ["unpacked", "pallas_pack"])
def test_build_tour_transport_variants(variant):
    """The construction rides the exchange layer, so both wire paths
    must produce the identical tour."""
    cfg = (CFG.with_(wire_packing=False) if variant == "unpacked"
           else CFG.with_(use_pallas_pack=True))
    parent = gen_tree_parents(60, 5)
    succ, _, _ = treealg.build_tour(parent, mesh1(), cfg=cfg)
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(succ))[:120],
        treealg.oracle_tour(60, parent).astype(np.int32))


def test_build_tour_weighted_weights():
    parent = gen_tree_parents(50, 7)
    succ, w, _ = treealg.build_tour(parent, mesh1(), cfg=CFG, weighted=True)
    succ_np = np.asarray(jax.device_get(succ))[:100]
    w_np = np.asarray(jax.device_get(w))[:100]
    idx = np.arange(100)
    term = succ_np == idx
    np.testing.assert_array_equal(w_np[term], 0)
    np.testing.assert_array_equal(w_np[~term & (idx % 2 == 0)], 1)
    np.testing.assert_array_equal(w_np[~term & (idx % 2 == 1)], -1)


def test_build_tour_rejects_bad_input():
    with pytest.raises(ValueError):
        treealg.build_tour(np.array([5, 0], np.int64), mesh1(), cfg=CFG)
    with pytest.raises(ValueError):
        treealg.build_tour(np.zeros(0, np.int64), mesh1(), cfg=CFG)
    forest = np.array([0, 1, 1], np.int64)
    with pytest.raises(ValueError, match="single-tree"):
        treealg.build_tour(forest, mesh1(), cfg=CFG, cut_at=2)


# --------------------------------------------------------------------------
# tree statistics vs the DFS oracle, per instance family
# --------------------------------------------------------------------------

FAMILIES = [
    ("gnm", 101, dict(locality=False)),       # GNM-BFS-like
    ("rgg2d", 102, dict(locality=True)),      # RGG2D-BFS-like
    ("gnm_forest", 103, dict(locality=False, num_trees=6)),
    ("rgg2d_forest", 104, dict(locality=True, num_trees=4)),
]


@pytest.mark.parametrize("name,seed,kw", FAMILIES)
def test_tree_stats_matches_dfs(name, seed, kw):
    parent = gen_tree_parents(120, seed=seed, **kw)
    st = treealg.tree_stats(parent, mesh1(), cfg=CFG)
    depth, size, pre, post = dfs_stats(parent)
    np.testing.assert_array_equal(st.depth, depth)
    np.testing.assert_array_equal(st.subtree_size, size)
    np.testing.assert_array_equal(st.preorder, pre)
    np.testing.assert_array_equal(st.postorder, post)


@pytest.mark.parametrize("name,seed,kw", FAMILIES)
def test_single_stat_fast_paths(name, seed, kw):
    parent = gen_tree_parents(90, seed=seed + 50, **kw)
    depth, size, _, _ = dfs_stats(parent)
    np.testing.assert_array_equal(
        treealg.node_depth(parent, mesh1(), cfg=CFG), depth)
    np.testing.assert_array_equal(
        treealg.subtree_size(parent, mesh1(), cfg=CFG), size)


def test_preorder_postorder_wrappers():
    parent = gen_tree_parents(60, 3, num_trees=2)
    _, _, pre, post = dfs_stats(parent)
    np.testing.assert_array_equal(
        treealg.preorder(parent, mesh1(), cfg=CFG), pre)
    np.testing.assert_array_equal(
        treealg.postorder(parent, mesh1(), cfg=CFG), post)


def test_singleton_trees():
    parent = np.arange(8, dtype=np.int64)  # 8 isolated roots
    st = treealg.tree_stats(parent, mesh1(), cfg=CFG)
    np.testing.assert_array_equal(st.depth, 0)
    np.testing.assert_array_equal(st.subtree_size, 1)
    np.testing.assert_array_equal(st.preorder, 0)
    np.testing.assert_array_equal(st.postorder, 0)


def test_weighted_int32_roundtrip_exact():
    """±1 int32 weights through the full solver are bit-exact (the
    chase_leaves weight-dtype plumbing): compare to the sequential
    oracle on a weighted device-built tour."""
    parent = gen_tree_parents(80, 11, locality=True)
    succ, w, n_pad = treealg.build_tour(parent, mesh1(), cfg=CFG,
                                        weighted=True)
    succ_np = np.asarray(jax.device_get(succ))
    w_np = np.asarray(jax.device_get(w))
    from repro.core.listrank import rank_list_with_stats
    s_ref, r_ref = rank_list_seq(succ_np, w_np)
    s, r, _ = rank_list_with_stats(succ_np, w_np, mesh1(), cfg=CFG)
    assert np.asarray(r).dtype == np.int32
    np.testing.assert_array_equal(np.asarray(s), s_ref)
    np.testing.assert_array_equal(np.asarray(r), r_ref)


# --------------------------------------------------------------------------
# re-rooting (edge orientation)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n,new_root,seed", [
    (2, 1, 0), (40, 17, 1), (40, 0, 2), (100, 99, 3), (77, 38, 4),
])
def test_root_tree(n, new_root, seed):
    parent = gen_tree_parents(n, seed)
    newp = treealg.root_tree(parent, new_root, mesh1(), cfg=CFG)
    assert newp[new_root] == new_root
    # same edge set, and a valid rooting (depths consistent)
    e_old = {frozenset((c, int(parent[c]))) for c in range(n)
             if parent[c] != c}
    e_new = {frozenset((c, int(newp[c]))) for c in range(n) if newp[c] != c}
    assert e_old == e_new
    depth, _, _, _ = dfs_stats(newp)
    assert depth[new_root] == 0 and (depth[np.arange(n) != new_root] > 0).all()


# --------------------------------------------------------------------------
# batched front door
# --------------------------------------------------------------------------

@pytest.mark.parametrize("parent", [
    [1, 0, 0],        # 2-cycle (collapses to spurious fixed points
                      # under jumping — the regression case)
    [1, 2, 0],        # 3-cycle
    [0, 2, 3, 1],     # root plus a cycle hanging off it
])
def test_roots_and_sizes_rejects_cycles(parent):
    with pytest.raises(ValueError, match="cycle"):
        treealg.roots_and_sizes(np.asarray(parent, np.int64))


def test_batch_rejects_out_of_range_ids():
    """Out-of-range ids must fail loudly BEFORE packing — after the
    offset relabeling they would silently alias into a neighboring
    instance's id window."""
    good = instances.gen_list(16, 1.0, seed=0)
    bad_succ = np.array([0, 5], np.int32)  # 5 out of range for n=2
    with pytest.raises(ValueError, match="out of range"):
        treealg.pack_instances([good, (bad_succ, np.zeros(2, np.int32))])
    with pytest.raises(ValueError, match="out of range"):
        treealg.solve_forest([np.array([0, 2]), np.array([0, 0, 1])],
                             mesh1(), cfg=CFG)


def test_pack_instances_int32_overflow_guard():
    """Offset relabeling must refuse batches whose packed ids would
    wrap int32 — checked on shapes BEFORE any elementwise work, so the
    boundary case costs no memory (broadcast views carry no data)."""
    big = np.broadcast_to(np.int32(0), (1 << 29,))
    zeros = np.broadcast_to(np.int32(0), (1 << 29,))
    with pytest.raises(ValueError, match="overflows the int32"):
        treealg.pack_instances([(big, zeros)] * 4)  # 2^31 ids
    # the guard threshold itself, exactly at the boundary
    limit = batch_lib.PACKED_ID_LIMIT
    batch_lib._check_packed_size(limit, "t")  # fits
    with pytest.raises(ValueError, match="split the batch"):
        batch_lib._check_packed_size(limit + 1, "t")
    # solve_forest guards the *arc* id space (2x the packed nodes)
    with pytest.raises(ValueError, match="overflows the int32"):
        treealg.solve_forest([np.broadcast_to(np.int64(0), (1 << 30,))],
                             mesh1(), cfg=CFG)


def test_is_ancestor_and_subtree_interval():
    """Closed-form ancestor/interval queries from pre/postorder —
    checked against explicit parent walking on a forest."""
    parent = gen_tree_parents(70, seed=13, num_trees=3)
    st = treealg.tree_stats(parent, mesh1(), cfg=CFG)
    n = st.n_nodes
    ref = np.zeros((n, n), bool)
    for x in range(n):
        w = x
        while True:
            ref[w, x] = True
            if parent[w] == w:
                break
            w = int(parent[w])
    got = st.is_ancestor(np.arange(n)[:, None], np.arange(n)[None, :])
    np.testing.assert_array_equal(got, ref)
    # scalar form + the subtree preorder interval
    lo, hi = st.subtree_interval(np.arange(n))
    for u in range(0, n, 7):
        assert bool(st.is_ancestor(u, u))
        inside = (st.root_of == st.root_of[u]) & \
            (st.preorder >= lo[u]) & (st.preorder <= hi[u])
        np.testing.assert_array_equal(inside, ref[u])
    # module-level function is the shared implementation
    np.testing.assert_array_equal(
        treealg.is_ancestor(st.preorder, st.postorder, st.root_of,
                            np.arange(n)[:, None], np.arange(n)[None, :]),
        ref)


def test_chase_wire_words_dtype_invariant():
    """The modeled-volume constant is weight-dtype independent: every
    supported dtype packs to one 32-bit wire word (api.chase_leaves)."""
    assert api_lib.chase_wire_words(jnp.int32) \
        == api_lib.chase_wire_words(jnp.float32) == api_lib.CHASE_WIRE_WORDS


def test_pack_unpack_roundtrip():
    batch = [instances.gen_list(33, 1.0, seed=s, num_lists=2) for s in
             range(3)]
    succ, rank, offsets = treealg.pack_instances(batch)
    assert succ.shape[0] == 99 and offsets[-1] == 99
    out = treealg.unpack_results(succ, rank, offsets)
    for (s0, r0), (s1, r1) in zip(batch, out):
        np.testing.assert_array_equal(s0, s1)
        np.testing.assert_array_equal(r0, r1)


def test_rank_lists_matches_oracle_and_single_invocation(monkeypatch):
    batch = [instances.gen_list(64, 1.0, seed=s) for s in range(3)]
    batch.append(instances.gen_random_lists(96, num_lists=4, seed=7,
                                            weighted=True))
    calls = []
    real = batch_lib.rank_list_with_stats

    def spy(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(batch_lib, "rank_list_with_stats", spy)
    results, stats = treealg.rank_lists_with_stats(batch, mesh1(), cfg=CFG)
    assert len(calls) == 1, "batch must cost ONE solver invocation"
    for (s_in, r_in), (s_out, r_out) in zip(batch, results):
        s_ref, r_ref = rank_list_seq(s_in, r_in)
        np.testing.assert_array_equal(s_out, s_ref)
        np.testing.assert_array_equal(r_out, r_ref)


def test_solve_forest_matches_per_tree():
    parents = [gen_tree_parents(n, seed=n) for n in (5, 16, 41, 64)]
    out = treealg.solve_forest(parents, mesh1(), cfg=CFG)
    for q, st in zip(parents, out):
        depth, size, pre, post = dfs_stats(q)
        np.testing.assert_array_equal(st.parent, q)
        np.testing.assert_array_equal(st.depth, depth)
        np.testing.assert_array_equal(st.subtree_size, size)
        np.testing.assert_array_equal(st.preorder, pre)
        np.testing.assert_array_equal(st.postorder, post)


def solver_collective_counts(n, mesh, cfg):
    """all_to_all (etc.) counts of the traced solver program for an
    n-element instance — the quantity the batched front door must keep
    flat versus a single-instance solve."""
    pe_axes = tuple(mesh.axis_names)
    plan = MeshPlan.from_mesh(mesh, pe_axes, None,
                              wire_packing=cfg.wire_packing)
    m = n // plan.p
    specs = api_lib.build_specs(cfg, plan, m, n, term_bound=8)
    fn = functools.partial(api_lib._solve_sharded, plan=plan, cfg=cfg,
                           specs=specs, m=m)
    mapped = compat.shard_map(
        fn, mesh=mesh, in_specs=(P(pe_axes), P(pe_axes), P()),
        out_specs=(P(pe_axes), P(pe_axes), P()), check_vma=False)
    succ = jnp.arange(n, dtype=jnp.int32)
    rank = jnp.zeros(n, jnp.int32)
    return introspect.collective_counts(mapped, succ, rank, jnp.int32(0))


def test_batched_solve_collective_count_equals_single():
    """Acceptance criterion: packing B instances into one solve keeps
    the per-round collective count of the mesh program identical to a
    single-instance solve — batching costs volume, never startups."""
    mesh = mesh1()
    single = solver_collective_counts(256, mesh, CFG)
    batched = solver_collective_counts(4 * 256, mesh, CFG)  # B=4 packed
    assert batched == single
    assert single.get("all_to_all", 0) > 0
