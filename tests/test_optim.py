"""Optimizer: convergence, int8/bf16 state parity, ZeRO sharding specs,
compression roundtrips (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import compat
from repro.optim import adamw, schedule
from repro.runtime import compression


def _quadratic_params():
    return {"w": jnp.asarray(np.random.default_rng(0).normal(size=(32,)),
                             jnp.float32)}


def _run(state_dtype, steps=300):
    cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0,
                            state_dtype=state_dtype)
    params = _quadratic_params()
    target = jnp.arange(32, dtype=jnp.float32) / 32
    opt = adamw.init(params, cfg)

    @jax.jit
    def step(params, opt):
        loss, grads = jax.value_and_grad(
            lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, opt, _ = adamw.update(grads, opt, params, cfg)
        return params, opt, loss

    for _ in range(steps):
        params, opt, loss = step(params, opt)
    return float(loss)


def test_adamw_converges_fp32():
    assert _run("float32") < 1e-4


@pytest.mark.parametrize("state_dtype", ["bfloat16", "int8"])
def test_adamw_low_precision_states_converge(state_dtype):
    assert _run(state_dtype) < 1e-2


def test_master_weights_keep_bf16_params_training():
    cfg = adamw.AdamWConfig(lr=1e-4, weight_decay=0.0, master_weights=True)
    params = {"w": jnp.ones((16,), jnp.bfloat16)}
    opt = adamw.init(params, cfg)
    grads = {"w": jnp.full((16,), 1e-3, jnp.float32)}
    p = params
    for _ in range(10):
        p, opt, _ = adamw.update(grads, opt, p, cfg)
    # bf16-only updates of 1e-4*direction would be lost to rounding;
    # master weights accumulate them
    assert float(jnp.abs(opt["master"]["w"] - 1.0).max()) > 0


def test_zero1_state_shardings_add_data_axis():
    import os
    from repro.models.params import spec
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    tree = {"w": spec((64, 32), ("embed", "mlp"))}
    sh = adamw.state_shardings(tree, mesh, adamw.AdamWConfig(), zero1=True)
    # with axis sizes 1 everything divides; the first unsharded dim of
    # the moment gets the data axis
    pspec = sh["m"]["w"].spec
    assert "data" in str(pspec)


def test_schedules():
    s = jnp.arange(0, 1000, 50)
    w = schedule.cosine_warmup(s, warmup_steps=100, total_steps=1000)
    assert float(w[0]) == 0.0
    assert float(w.max()) <= 1.0
    assert float(w[-1]) >= 0.1 - 1e-6
    r = schedule.rsqrt(s, warmup_steps=100)
    assert float(r.max()) <= 1.0


# ------------------------------------------------------------- compression
@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 2000), scale=st.floats(1e-3, 1e3),
       seed=st.integers(0, 99))
def test_qint8_roundtrip_error_bound(n, scale, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n,)) * scale, jnp.float32)
    q = compression.QInt8.quantize(x)
    err = np.abs(np.asarray(q.dequantize()) - np.asarray(x))
    # blockwise absmax scaling: error <= scale_block / 2 per element
    blocks = np.asarray(q.scale)
    bound = np.repeat(blocks, compression.BLOCK)[:n] * 0.5 + 1e-7
    assert (err <= bound).all()


def test_qint8_shapes_and_zeros():
    q = compression.QInt8.zeros((3, 5, 7))
    assert q.dequantize().shape == (3, 5, 7)
    x = jnp.zeros((3, 5, 7))
    np.testing.assert_array_equal(np.asarray(q.dequantize()), np.asarray(x))


def test_error_feedback_unbiased_over_steps():
    """Error feedback: the accumulated applied signal converges to the
    true sum even with coarse quantization."""
    rng = np.random.default_rng(0)
    true = jnp.asarray(rng.normal(size=(512,)), jnp.float32) * 1e-4
    err = jnp.zeros_like(true)
    applied = jnp.zeros_like(true)
    for _ in range(200):
        xc = true + err
        q = compression.QInt8.quantize(xc)
        deq = q.dequantize()
        err = xc - deq
        applied = applied + deq
    np.testing.assert_allclose(np.asarray(applied / 200), np.asarray(true),
                               atol=1e-6)
