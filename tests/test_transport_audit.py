"""Transport-layer audit: nothing may bypass the Transport abstraction.

The simshard backend only emulates collectives that go through
``MeshPlan``'s transport delegates; a raw ``lax.psum(.., axis_names)``
buried in an algorithm module would trace fine on a mesh and still work
under vmap TODAY — but it would dodge the simulated-collective markers
(silently corrupting every collective-count pin) and any future
transport (e.g. a ppermute-based torus backend). The audit found these
bypass sites when the abstraction was introduced: ``api.py`` (restore/
reversal miss counts, stats reduction), ``srs.py`` (chase/gather
convergence psums), ``doubling.py`` (pending psum + the 4-array
all-gather base case), ``treealg/euler.py`` (tour stats),
``graphalg/cc.py`` (hooking loop) and ``graphalg/frontdoor.py``
(pipeline stats). Each gets (a) a static source scan proving it stays
fixed and (b) an executing simshard regression through that exact path.
"""
import pathlib
import re

import numpy as np
import pytest

from repro.core.listrank import (ListRankConfig, IndirectionSpec, instances,
                                 rank_list_seq, rank_list_with_stats,
                                 sim_mesh)

SRC = pathlib.Path(__file__).parent.parent / "src" / "repro" / "core"

#: the only module allowed to touch lax collectives (the backends live
#: there); everything else must go through plan.psum/all_to_all/...
ALLOWED = {"listrank/transport.py"}

_COLLECTIVE_RE = re.compile(
    r"lax\s*\.\s*(psum|all_to_all|all_gather|axis_index|ppermute|pmax|pmin"
    r"|reduce_scatter)\s*\(")

CFG = ListRankConfig(srs_rounds=1, local_contraction=True)


def _scan_for_collectives(root: pathlib.Path, allowed: set) -> list[str]:
    offenders = []
    for f in sorted(root.rglob("*.py")):
        rel = f.relative_to(root).as_posix()
        if rel in allowed:
            continue
        for i, line in enumerate(f.read_text().splitlines(), 1):
            if _COLLECTIVE_RE.search(line.split("#")[0]):
                offenders.append(f"{rel}:{i}: {line.strip()}")
    return offenders


def test_no_collective_bypasses_in_core():
    """Static scan: no raw lax collective calls outside transport.py."""
    assert _scan_for_collectives(SRC, ALLOWED) == []


def test_no_collectives_in_obs_layer():
    """The observability/telemetry layer is host code plus per-PE jnp
    arithmetic: zero lax collectives anywhere under src/repro/obs, so
    the telemetry plane cannot add collectives to any traced program
    (the zero-added-collectives rule, pinned live below)."""
    assert _scan_for_collectives(SRC.parent / "obs", set()) == []


@pytest.mark.telemetry
@pytest.mark.parametrize("p", (8, 256))
def test_stage_collective_counts_identical_telemetry_on_off(p):
    """cfg.telemetry=True compiles a different program (extra per-PE
    outputs) but may not add a single collective: the per-stage traced
    collective counts, solve output bytes, and integer counters are
    identical to the telemetry-off run at small and large p."""
    n = 8 * p
    s, r = instances.gen_list(n, gamma=1.0, seed=9)
    cfg = ListRankConfig(srs_rounds=1, local_contraction=True)
    out = {}
    for tag, c in (("off", cfg), ("on", cfg.with_(telemetry=True))):
        sf, rf, stats = rank_list_with_stats(
            s, r, sim_mesh(p), cfg=c, seed=1, stage_counters=True,
            term_bound=1)
        stats.pop("telemetry", None)
        out[tag] = (np.asarray(sf).tobytes(), np.asarray(rf).tobytes(),
                    stats["stage_collectives"],
                    {k: v for k, v in stats.items() if isinstance(v, int)})
    assert out["on"] == out["off"]
    assert any(dict(c).get("all_to_all", 0) > 0
               for _, c in out["on"][2])


def _solve_and_check(succ, rank, mesh, cfg, **kw):
    s_ref, r_ref = rank_list_seq(succ, rank)
    s, r, stats = rank_list_with_stats(succ, rank, mesh, cfg=cfg, **kw)
    assert np.array_equal(np.asarray(s), s_ref), stats
    assert np.array_equal(np.asarray(r), r_ref), stats
    return stats


def test_api_restore_psums_simshard():
    """api._restore_local miss counts (former lax.psum x2) — the local-
    contraction restore path must converge under simshard."""
    succ, rank = instances.gen_list(512, gamma=0.5, seed=31)
    st = _solve_and_check(succ, rank, sim_mesh(16), CFG)
    assert st["undelivered"] == 0


def test_api_reversal_psums_simshard():
    """_reverse_instance / route_until_done pendings (former lax.psum)
    — the faithful Algorithm-1 reversal preprocessing under simshard."""
    succ, rank = instances.gen_list(512, gamma=1.0, seed=32)
    _solve_and_check(succ, rank, sim_mesh(16),
                     CFG.with_(avoid_reversal=False))


def test_api_reversal_on_tours_simshard():
    """Faithful Algorithm-1 reversal on Euler-tour instances — both
    tree models, a ±1-weighted forest, and a grid-indirection variant
    (the coverage the deleted subprocess matrix carried since PR 3,
    now in-process)."""
    rev = CFG.with_(avoid_reversal=False)
    cases = [
        (dict(seed=41, locality=False), rev, sim_mesh(8), None),
        (dict(seed=42, locality=True, weighted=True, num_trees=5),
         rev.with_(srs_rounds=2), sim_mesh(8), None),
        (dict(seed=43, locality=False), rev,
         sim_mesh((2, 4), ("row", "col")),
         IndirectionSpec.grid(("row", "col"))),
    ]
    for kw, cfg, mesh, ind in cases:
        s, r, _ = instances.gen_euler_tour(257, **kw)
        s, r = instances.pad_to_multiple(s, r, 8)
        _solve_and_check(s, r, mesh, cfg, indirection=ind)


def test_srs_grid_indirection_psums_simshard():
    """srs chase/gather convergence psums over a 2-hop grid plan on a
    2D virtual mesh (single-axis hops of a multi-axis axis set)."""
    succ, rank = instances.gen_list(512, gamma=1.0, seed=33)
    _solve_and_check(succ, rank, sim_mesh((4, 8), ("row", "col")), CFG,
                     indirection=IndirectionSpec.grid(("row", "col")))


def test_srs_topology_indirection_simshard():
    """Topology-aware indirection through the FULL solver (intra-node
    hop first): the end-to-end coverage the deleted subprocess matrix's
    'srs1 topo' case carried, now on the virtual mesh."""
    succ, rank = instances.gen_list(512, gamma=1.0, seed=44)
    _solve_and_check(succ, rank, sim_mesh((4, 8), ("row", "col")), CFG,
                     indirection=IndirectionSpec.topology(("col",),
                                                          ("row",)))


def test_doubling_allgather_base_simshard():
    """doubling.allgather_solve (former 4x lax.all_gather over the
    tuple of PE axes — the one collective whose vmap batching rule
    rejects multi-axis gathers outright, decomposed inside the
    simshard_all_gather marker)."""
    succ, rank = instances.gen_random_lists(512, num_lists=5, seed=34,
                                            weighted=True)
    _solve_and_check(succ, rank, sim_mesh((2, 8), ("row", "col")),
                     CFG.with_(base_case="allgather"))


def test_doubling_pending_psum_simshard():
    succ, rank = instances.gen_list(512, gamma=1.0, seed=35)
    _solve_and_check(succ, rank, sim_mesh(32),
                     CFG.with_(algorithm="doubling"))


def test_euler_tour_stats_psums_simshard():
    """treealg.euler tour stats (former lax.psum x2): device tour
    construction on a virtual mesh matches the host oracle."""
    import jax
    from repro.core import treealg
    parent = instances.gen_tree_parents(301, seed=36, locality=True,
                                        num_trees=3)
    succ, w, _ = treealg.build_tour(parent, sim_mesh(16), cfg=CFG)
    got = np.asarray(jax.device_get(succ))[:2 * 301]
    want = treealg.oracle_tour(301, parent).astype(np.int32)
    np.testing.assert_array_equal(got, want)


def test_graphalg_cc_psums_simshard():
    """graphalg.cc hooking-loop psums (former lax.psum x5) + frontdoor
    pipeline stats: components and stats on a virtual mesh."""
    from _graph_oracles import union_find_labels
    from repro.core import graphalg
    edges = instances.gen_graph_edges(200, 300, seed=37, num_components=4)
    labels, st = graphalg.connected_components(edges, 200, sim_mesh(16),
                                               cfg=CFG)
    np.testing.assert_array_equal(labels, union_find_labels(200, edges))
    assert st["cc_unconverged"] == 0


def test_simshard_rejects_pallas_kernels():
    """The batched trace can't honor the Pallas kernels; the front door
    must fail loudly, not corrupt results."""
    succ, rank = instances.gen_list(64, gamma=0.0, seed=38)
    for bad in (CFG.with_(use_pallas=True), CFG.with_(use_pallas_pack=True)):
        with pytest.raises(ValueError, match="Pallas"):
            rank_list_with_stats(succ, rank, sim_mesh(8), cfg=bad)


def test_mesh_backend_rejects_sim_mesh():
    succ, rank = instances.gen_list(64, gamma=0.0, seed=39)
    with pytest.raises(ValueError, match="real device mesh"):
        rank_list_with_stats(succ, rank, sim_mesh(8),
                             cfg=CFG.with_(backend="mesh"))


def test_forced_simshard_on_real_mesh():
    """backend='simshard' with a real mesh: same axis names/sizes, no
    device placement — the escape hatch for large-p runs on any host."""
    from repro import compat
    succ, rank = instances.gen_list(128, gamma=1.0, seed=40)
    mesh = compat.make_mesh((1,), ("pe",))
    st = _solve_and_check(succ, rank, mesh, CFG.with_(backend="simshard"))
    assert st["attempts"] >= 1
